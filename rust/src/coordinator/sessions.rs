//! Decode-session management: sticky session→lane placement, a shared
//! paged KV-cache pool, and iteration-level wave execution.
//!
//! Prefill requests are stateless and batchable ([`super::batcher`]);
//! decode is the opposite — each session owns a growing K/V cache, so
//! routing must be **sticky**: every step of a session runs on the
//! decode pipeline (pool *lane*) the session was opened on.
//! [`SessionTable`] is the pure (thread-free, clock-free) core that
//! enforces this:
//!
//! * `open(d)` admits a session under a [`DecodeClass`] (the head
//!   dimension — the only shape that must stay fixed; the sequence
//!   length grows per step), pins it to the lowest free pool lane, and
//!   backs it with a paged [`PagedDecodeSession`] whose K/V rows live
//!   in fixed-size blocks of **one shared bounded [`BlockPool`]**.
//! * `fork(parent)` admits a new session sharing the parent's entire
//!   cached prefix at zero copies (refcounted blocks, copy-on-write on
//!   the tail at the first divergent append).
//! * `step(req)` validates and runs one decode step alone (the
//!   standalone path the differential tests compare against).
//! * `step_wave(reqs)` is the continuous-batching path: it stages at
//!   most one step per session — **transactionally**: block
//!   allocations of a failed wave unwind row by row — builds one
//!   engine with one decode pipeline per lane
//!   ([`build_decode_lanes_rows`]), runs them spatially, and commits
//!   every lane's row. Lanes share no channels, so each row is
//!   bit-identical to the same step run alone — enforced by
//!   `tests/continuous_batching.rs` and `tests/paged_conformance.rs`.
//! * `close(id)` retires the session, returns its transcript, releases
//!   its block references, and reclaims the lane (lowest-index reuse).
//!
//! **Admission is deferred, not refused.** A full session table, an
//! exhausted lane pool, or an exhausted block pool all surface as
//! [`Error::AdmissionDeferred`] — the typed signal that the request is
//! valid and should be retried once capacity frees. The serving loop
//! requeues deferred work; only genuine errors (unknown session,
//! sticky-class violation, an unwindowed session stepping past
//! `max_len`, a session too large for the whole pool) hard-fail.
//!
//! **Sliding windows.** `open_windowed(d, w)` admits a session whose
//! attention is the sliding window of
//! [`Mask::Window`](crate::attention::workload::Mask::Window): each
//! step attends only the last `w` cached rows, and the paged table
//! recycles blocks whose rows slide wholly out of the window (ring
//! eviction in [`crate::runtime::kvcache`]). The window is an
//! **attention semantic**, not an admission limit: a windowed session
//! decodes indefinitely — `max_len` does not apply — while holding at
//! most `⌈w / block_size⌉` blocks, so arbitrarily long sessions stay
//! admissible against a finite pool.
//!
//! **Preemption.** When a step cannot get a block, the table swaps out
//! a victim session (lowest [`Priority`] class first; within a class
//! the resident one with the most exclusively-owned blocks, ties to the
//! lowest id; when every candidate's blocks are shared, the one holding
//! the most references — dropping refcounts so the next retry finds
//! exclusive blocks) and retries. Victims restore bit-exactly on their
//! next step, so a preempt/requeue cycle cannot perturb any transcript
//! — the conformance suite's acceptance property. Sessions already
//! staged in the current wave are never victims (their rows are wired
//! into the running engine).
//!
//! **Chunked prefill & mixed waves** ([`SessionTable::wave`]). A
//! session opened with a prompt ([`SessionTable::open_with_spec`])
//! ingests it across waves in planner-granted chunks: whole prompt
//! rows, or — on the memory-free mapping with no window — *partial*
//! rows whose online-softmax state ([`SoftmaxCarry`]) carries between
//! waves, piggybacking beside ordinary decode steps in the same engine
//! ([`build_mixed_wave`]). A chunk of R rows runs as R spatial
//! sub-pipelines in one wave, so a P-row prompt reaches its first
//! decode token in ⌈P/chunk⌉ waves instead of P — the TTFT win the
//! budgeted scheduler buys — while every grant stays transactional
//! like a decode wave and the finished transcript stays bit-identical
//! to the unchunked session (`tests/sched_conformance.rs`). Decode
//! steps and forks on a mid-prefill session are hard errors (the
//! serving loop queues them until the prompt completes); windowed
//! prompts ingest one whole row per wave, because their ring evicts in
//! place and a later row's append could overwrite rows an earlier
//! row's gather still needs.

use std::collections::HashMap;

use super::request::{DecodeClass, DecodeStepRequest, DecodeStepResponse};
use super::sched::Priority;
use crate::attention::decode::{DecodeKind, PagedDecodeSession, SoftmaxCarry};
use crate::attention::multihead::{
    build_decode_lanes_rows, build_mixed_wave, LaneChunkRows, LaneStepRows, LaneWork,
};
use crate::attention::reference::Matrix;
use crate::attention::DepthPolicy;
use crate::runtime::kvcache::{AppendUndo, BlockPool, KvCacheConfig};
use crate::sim::SchedulerMode;
use crate::{Error, Result};

/// Session-table policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Which decode-step mapping sessions run on.
    pub kind: DecodeKind,
    /// Pool width: independent decode lanes, each holding at most one
    /// session. Bounds concurrency alongside `max_sessions`.
    pub lanes: usize,
    /// Maximum concurrently open sessions (admission control).
    pub max_sessions: usize,
    /// Maximum tokens an *unwindowed* session may decode. Sessions
    /// opened with [`SessionTable::open_windowed`] are exempt: their
    /// window bounds what a step *attends* (enforced by ring
    /// eviction), not how long the session may run.
    pub max_len: usize,
    /// Scheduler mode pinned onto every step/wave engine (`None` = the
    /// engine default, i.e. `SDPA_SCHED`). Differential tests pin both.
    pub mode: Option<SchedulerMode>,
    /// Worker-thread count pinned onto every step/wave engine (`None` =
    /// the engine default, i.e. `SDPA_THREADS`). A decode wave compiles
    /// one connected component per lane, so this is the wave's
    /// parallelism knob; results are bit-identical for every value.
    pub threads: Option<usize>,
    /// Paged KV-cache geometry: every session's K/V rows come from one
    /// shared pool of `kv.num_blocks` blocks of `kv.block_size` rows.
    pub kv: KvCacheConfig,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            kind: DecodeKind::MemoryFree,
            lanes: 8,
            max_sessions: 64,
            max_len: 4096,
            mode: None,
            threads: None,
            kv: KvCacheConfig::default(),
        }
    }
}

struct Entry {
    class: DecodeClass,
    lane: usize,
    priority: Priority,
    /// The prompt still being ingested, if any. While this is `Some`,
    /// decode steps and forks are refused.
    prefill: Option<PendingPrefill>,
    session: PagedDecodeSession,
}

/// An admitted prompt still being ingested. The cache invariant is
/// `session.len() == next_row + (keys_done > 0) as usize`: a row's
/// `(k, v)` is appended when its first segment stages, so a mid-row
/// split leaves exactly one cached row ahead of the finished outputs.
struct PendingPrefill {
    /// Per-row query rows of the prompt.
    q: Vec<Vec<f32>>,
    /// Per-row key rows.
    k: Vec<Vec<f32>>,
    /// Per-row value rows.
    v: Vec<Vec<f32>>,
    /// Prompt rows fully ingested (one output row pushed per row).
    next_row: usize,
    /// Keys of row `next_row` already folded into `carry`.
    keys_done: usize,
    /// Online-softmax state of the partially scanned row.
    carry: SoftmaxCarry,
}

/// A prompt to ingest at open time: per-row q/k/v, all of the
/// session's head dimension. Row `t`'s output attends rows `0..=t`, so
/// a fully ingested prompt's outputs are bit-identical to stepping the
/// same rows through a decode session one by one.
#[derive(Clone, Debug, Default)]
pub struct PrefillPrompt {
    /// Query rows, one per prompt token.
    pub q: Vec<Vec<f32>>,
    /// Key rows.
    pub k: Vec<Vec<f32>>,
    /// Value rows.
    pub v: Vec<Vec<f32>>,
}

impl PrefillPrompt {
    /// Prompt length in rows.
    pub fn len(&self) -> usize {
        self.k.len()
    }

    /// Whether the prompt has no rows.
    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }
}

/// One planned chunk segment of a staged prefill grant.
#[derive(Clone, Copy, Debug)]
struct SegPlan {
    /// Prompt row index.
    row: usize,
    /// Keys of the row already scanned before this segment.
    kd: usize,
    /// Keys this segment scans.
    take: usize,
    /// Whether the segment reaches the row's last visible key (it then
    /// emits the output row instead of a packed carry).
    finalize: bool,
}

/// One wave member staged and awaiting the engine run.
enum StagedItem {
    Step {
        i: usize,
        id: u64,
        class: DecodeClass,
    },
    Prefill {
        i: usize,
        id: u64,
        rows_total: usize,
        segs: Vec<SegPlan>,
        undos: Vec<AppendUndo>,
    },
}

/// One request in a mixed scheduling wave: a pending decode step or a
/// planner-granted slice of a session's prompt ingestion.
#[derive(Clone, Debug)]
pub enum WaveRequest {
    /// Run the session's next decode step.
    Step(DecodeStepRequest),
    /// Advance the session's pending prefill by at most `max_rows`
    /// prompt rows / `max_keys` keys (a [`super::sched::plan_wave`]
    /// grant; the table stages the actual segments).
    Prefill {
        /// Session id.
        session: u64,
        /// Row grant (a mid-row continuation counts as one row).
        max_rows: usize,
        /// Key grant across the granted rows.
        max_keys: usize,
    },
}

impl WaveRequest {
    /// The session the request targets.
    pub fn session(&self) -> u64 {
        match self {
            WaveRequest::Step(req) => req.session,
            WaveRequest::Prefill { session, .. } => *session,
        }
    }
}

/// How far a prefill grant got in one wave.
#[derive(Clone, Debug, PartialEq)]
pub struct PrefillProgress {
    /// Session id.
    pub session: u64,
    /// Prompt rows fully ingested after this wave.
    pub rows_done: usize,
    /// Total prompt rows.
    pub rows_total: usize,
    /// Whether the prompt is now fully ingested (decode may begin).
    pub done: bool,
    /// The session's sticky lane.
    pub lane: usize,
    /// Sessions co-scheduled in the wave.
    pub wave_lanes: usize,
    /// Engine cycles the wave took.
    pub cycles: u64,
}

/// One wave request's result.
#[derive(Clone, Debug)]
pub enum WaveOutcome {
    /// A decode step's response.
    Step(DecodeStepResponse),
    /// A prefill grant's progress.
    Prefill(PrefillProgress),
}

/// The decode-session coordinator core.
pub struct SessionTable {
    cfg: SessionConfig,
    next_id: u64,
    sessions: HashMap<u64, Entry>,
    /// `lane_owner[l]` = session currently pinned to lane `l`.
    lane_owner: Vec<Option<u64>>,
    /// The shared paged KV-cache pool backing every session.
    pool: BlockPool,
    steps_served: u64,
    preemptions: u64,
}

impl SessionTable {
    /// New table under a policy. The config is caller input, so a
    /// degenerate one (zero lanes / sessions / window / blocks) is an
    /// `Err`, not a panic.
    pub fn new(cfg: SessionConfig) -> Result<Self> {
        if cfg.lanes == 0 || cfg.max_sessions == 0 || cfg.max_len == 0 {
            return Err(Error::Coordinator(
                "session config needs lanes ≥ 1, max_sessions ≥ 1 and max_len ≥ 1".into(),
            ));
        }
        Ok(SessionTable {
            lane_owner: vec![None; cfg.lanes],
            pool: BlockPool::new(cfg.kv)?,
            cfg,
            next_id: 0,
            sessions: HashMap::new(),
            steps_served: 0,
            preemptions: 0,
        })
    }

    /// Claim a session slot and the lowest free lane, or defer.
    fn admit_slot(&self) -> Result<usize> {
        if self.sessions.len() >= self.cfg.max_sessions {
            return Err(Error::AdmissionDeferred(format!(
                "session table full ({} active)",
                self.sessions.len()
            )));
        }
        self.lane_owner
            .iter()
            .position(Option::is_none)
            .ok_or_else(|| {
                Error::AdmissionDeferred(format!(
                    "no free lane ({} lanes busy)",
                    self.cfg.lanes
                ))
            })
    }

    /// Open a session for head dimension `d`; returns its id. Admission
    /// needs both a session slot and a free lane — when either is
    /// exhausted the result is [`Error::AdmissionDeferred`], the typed
    /// retry signal the serving loop requeues on (a hard reject here
    /// used to strand burst traffic with no retry path). The session is
    /// pinned to the lowest free lane (closed sessions' lanes are
    /// reclaimed).
    pub fn open(&mut self, d: usize) -> Result<u64> {
        self.open_with_spec(d, None, Priority::default(), None)
    }

    /// Open a **sliding-window** session for head dimension `d`: every
    /// step attends only the last `window` cached rows, and the paged
    /// table recycles blocks that slide wholly out of the window, so
    /// the session never holds more than `⌈window / block_size⌉`
    /// blocks and `max_len` does not apply (the window is an attention
    /// semantic, not an admission limit). Admission control and lane
    /// placement match [`Self::open`].
    pub fn open_windowed(&mut self, d: usize, window: usize) -> Result<u64> {
        self.open_with_spec(d, Some(window), Priority::default(), None)
    }

    /// Open a session with the full spec: head dimension, optional
    /// sliding window, [`Priority`] class, and an optional prompt to
    /// ingest via chunked prefill. A prompted session cannot decode (or
    /// fork) until its prompt is fully ingested by [`Self::wave`]
    /// grants; an empty prompt is the same as none. Prompt shapes are
    /// validated here, once: ragged row counts, rows of the wrong
    /// dimension, and unwindowed prompts longer than `max_len` are hard
    /// errors.
    pub fn open_with_spec(
        &mut self,
        d: usize,
        window: Option<usize>,
        priority: Priority,
        prompt: Option<PrefillPrompt>,
    ) -> Result<u64> {
        if d == 0 {
            return Err(Error::Coordinator(
                "decode session needs a head dimension ≥ 1".into(),
            ));
        }
        if window == Some(0) {
            return Err(Error::Coordinator(
                "a sliding-window session needs a window ≥ 1".into(),
            ));
        }
        if let Some(p) = &prompt {
            if p.q.len() != p.k.len() || p.k.len() != p.v.len() {
                return Err(Error::Coordinator(format!(
                    "prompt rows are ragged: {} q, {} k, {} v rows",
                    p.q.len(),
                    p.k.len(),
                    p.v.len()
                )));
            }
            for (what, rows) in [("q", &p.q), ("k", &p.k), ("v", &p.v)] {
                if let Some(row) = rows.iter().find(|r| r.len() != d) {
                    return Err(Error::Coordinator(format!(
                        "prompt {what} row has dim {}, session expects {d}",
                        row.len()
                    )));
                }
            }
            if window.is_none() && p.len() > self.cfg.max_len {
                return Err(Error::Coordinator(format!(
                    "prompt of {} rows exceeds the context window ({} tokens)",
                    p.len(),
                    self.cfg.max_len
                )));
            }
        }
        let lane = self.admit_slot()?;
        let id = self.next_id;
        self.next_id += 1;
        let mut session = match window {
            Some(w) => PagedDecodeSession::new_windowed(self.cfg.kind, d, w),
            None => PagedDecodeSession::new(self.cfg.kind, d),
        };
        if let Some(mode) = self.cfg.mode {
            session.set_scheduler_mode(mode);
        }
        if let Some(th) = self.cfg.threads {
            session.set_threads(th);
        }
        self.lane_owner[lane] = Some(id);
        self.sessions.insert(
            id,
            Entry {
                class: DecodeClass { d },
                lane,
                priority,
                prefill: prompt.filter(|p| !p.is_empty()).map(|p| PendingPrefill {
                    carry: SoftmaxCarry::fresh(d),
                    next_row: 0,
                    keys_done: 0,
                    q: p.q,
                    k: p.k,
                    v: p.v,
                }),
                session,
            },
        );
        Ok(id)
    }

    /// Open a session **forked from `parent`**: the child shares the
    /// parent's entire cached prefix (refcounted blocks, zero copies;
    /// copy-on-write on the first divergent append) and starts with an
    /// empty transcript. Admission control and lane placement match
    /// [`Self::open`]; an unknown parent is a hard error, a full table
    /// or pool defers.
    pub fn fork(&mut self, parent: u64) -> Result<u64> {
        match self.sessions.get(&parent) {
            None => {
                return Err(Error::Coordinator(format!(
                    "unknown decode session {parent}"
                )))
            }
            Some(entry) if entry.prefill.is_some() => {
                return Err(Error::Coordinator(format!(
                    "session {parent} is still prefilling its prompt; fork after it completes"
                )))
            }
            Some(_) => {}
        }
        let lane = self.admit_slot()?;
        // A preempted parent must be resident to share its blocks.
        self.ensure_resident(parent, &[parent])?;
        let (class, priority, child) = {
            let entry = self.sessions.get(&parent).expect("checked above");
            (
                entry.class,
                entry.priority,
                entry.session.fork(&mut self.pool)?,
            )
        };
        let id = self.next_id;
        self.next_id += 1;
        self.lane_owner[lane] = Some(id);
        self.sessions.insert(
            id,
            Entry {
                class,
                lane,
                priority,
                prefill: None,
                session: child,
            },
        );
        Ok(id)
    }

    /// The sticky class a session was opened with.
    pub fn class_of(&self, id: u64) -> Option<DecodeClass> {
        self.sessions.get(&id).map(|e| e.class)
    }

    /// The pool lane a session is pinned to.
    pub fn lane_of(&self, id: u64) -> Option<usize> {
        self.sessions.get(&id).map(|e| e.lane)
    }

    /// Tokens a session has decoded so far (its step counter).
    pub fn len_of(&self, id: u64) -> Option<usize> {
        self.sessions.get(&id).map(|e| e.session.len())
    }

    /// Blocks a session's table currently references (0 while
    /// preempted).
    pub fn blocks_of(&self, id: u64) -> Option<usize> {
        self.sessions.get(&id).map(|e| e.session.table().num_blocks())
    }

    /// Whether a session's cache is currently swapped out.
    pub fn is_preempted(&self, id: u64) -> Option<bool> {
        self.sessions.get(&id).map(|e| e.session.is_preempted())
    }

    /// Pool width (configured lanes).
    pub fn lanes(&self) -> usize {
        self.cfg.lanes
    }

    /// Lanes currently pinned to a session.
    pub fn lanes_in_use(&self) -> usize {
        self.lane_owner.iter().filter(|o| o.is_some()).count()
    }

    /// Lanes currently free — the admission headroom the fleet router's
    /// least-loaded placement reads.
    pub fn free_lanes(&self) -> usize {
        self.cfg.lanes - self.lanes_in_use()
    }

    /// Maximum concurrently open sessions (config accessor).
    pub fn max_sessions(&self) -> usize {
        self.cfg.max_sessions
    }

    /// Total blocks in the shared KV-cache pool.
    pub fn pool_capacity(&self) -> usize {
        self.pool.capacity()
    }

    /// Blocks currently allocated from the pool.
    pub fn pool_used_blocks(&self) -> usize {
        self.pool.used_blocks()
    }

    /// Blocks currently free in the pool.
    pub fn pool_free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Allocated blocks referenced by more than one session — the
    /// prefix-sharing win.
    pub fn pool_shared_blocks(&self) -> usize {
        self.pool.shared_blocks()
    }

    /// Rows per block in the shared pool.
    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    /// Sessions preempted (swapped out) so far — monotonic counter.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Rows recycled by sliding-window ring eviction so far, across
    /// every session on the shared pool — monotonic counter.
    pub fn pool_evictions(&self) -> u64 {
        self.pool.evictions()
    }

    /// The sliding window a session was opened with (`Some(None)` for
    /// a full-context session, `None` for an unknown id).
    pub fn window_of(&self, id: u64) -> Option<Option<usize>> {
        self.sessions.get(&id).map(|e| e.session.window())
    }

    /// The [`Priority`] class a session was opened with.
    pub fn priority_of(&self, id: u64) -> Option<Priority> {
        self.sessions.get(&id).map(|e| e.priority)
    }

    /// Prompt rows a session has yet to ingest (`Some(0)` once prefill
    /// completed or the session never had a prompt).
    pub fn prefill_remaining(&self, id: u64) -> Option<usize> {
        self.sessions.get(&id).map(|e| {
            e.prefill
                .as_ref()
                .map(|pf| pf.k.len() - pf.next_row)
                .unwrap_or(0)
        })
    }

    /// Pending-prefill shape for wave planning: `(rows_total, next_row,
    /// keys_done, splittable)`. `None` when the id is unknown or the
    /// prompt is fully ingested. `splittable` means rows may stop
    /// mid-scan and resume by carry — the memory-free mapping with no
    /// sliding window.
    pub fn prefill_state(&self, id: u64) -> Option<(usize, usize, usize, bool)> {
        let entry = self.sessions.get(&id)?;
        let pf = entry.prefill.as_ref()?;
        Some((
            pf.k.len(),
            pf.next_row,
            pf.keys_done,
            entry.session.kind() == DecodeKind::MemoryFree && entry.session.window().is_none(),
        ))
    }

    /// Validate one step request against the table and its session;
    /// returns the session's class.
    fn admit_step(&self, req: &DecodeStepRequest) -> Result<DecodeClass> {
        let class = req.class()?;
        let entry = self.sessions.get(&req.session).ok_or_else(|| {
            Error::Coordinator(format!("unknown decode session {}", req.session))
        })?;
        if class != entry.class {
            return Err(Error::Coordinator(format!(
                "sticky routing violation: session {} was opened for {}, step is {}",
                req.session, entry.class, class
            )));
        }
        if entry.prefill.is_some() {
            return Err(Error::Coordinator(format!(
                "session {} is still prefilling its prompt; decode steps must wait",
                req.session
            )));
        }
        // A sliding-window session is exempt from `max_len`: its
        // window caps what a step attends (and what the ring holds),
        // not how long the session may run.
        if entry.session.window().is_none() && entry.session.len() >= self.cfg.max_len {
            return Err(Error::Coordinator(format!(
                "session {} exceeded the context window ({} tokens)",
                req.session, self.cfg.max_len
            )));
        }
        Ok(class)
    }

    /// Swap out the resident session (outside `exclude`) that frees the
    /// most blocks; ties go to the lowest id so victim choice is
    /// deterministic. When no candidate owns an exclusive block — e.g.
    /// a fork family whose blocks are all shared at refcount > 1 — the
    /// fallback preempts the candidate holding the *most* block
    /// references: that frees nothing immediately but drops the
    /// refcounts, so the next call (every caller retries in a loop)
    /// finds exclusive blocks and reclaims them. Each call strictly
    /// decreases the total reference count, so the retry loops
    /// terminate. Returns whether anything was preempted.
    fn preempt_victim(&mut self, exclude: &[u64]) -> bool {
        // (priority rank, exclusive blocks, total block refs, id) per
        // candidate: lower service classes (higher rank) are preferred
        // victims; within a class the block metrics decide as before.
        let mut best_exclusive: Option<(u8, usize, u64)> = None;
        let mut best_any: Option<(u8, usize, u64)> = None;
        for (&id, entry) in &self.sessions {
            if exclude.contains(&id) || entry.session.is_preempted() {
                continue;
            }
            let held = entry.session.table().num_blocks();
            if held == 0 {
                continue;
            }
            let rank = entry.priority.rank();
            let freed = self.pool.exclusive_blocks(entry.session.table());
            let better = |best: Option<(u8, usize, u64)>, score: usize| match best {
                None => true,
                Some((br, bs, bid)) => {
                    rank > br
                        || (rank == br && (score > bs || (score == bs && id < bid)))
                }
            };
            if freed > 0 && better(best_exclusive, freed) {
                best_exclusive = Some((rank, freed, id));
            }
            if better(best_any, held) {
                best_any = Some((rank, held, id));
            }
        }
        let Some((_, _, victim)) = best_exclusive.or(best_any) else {
            return false;
        };
        let entry = self.sessions.get_mut(&victim).expect("selected above");
        entry.session.preempt(&mut self.pool);
        self.preemptions += 1;
        true
    }

    /// Hard cap: a cache of `rows` rows that cannot fit the pool even
    /// alone can never be served — that is a configuration error, not a
    /// deferral (deferring it would livelock the retry loop). A
    /// windowed session only ever needs its ring
    /// (`⌈window / block_size⌉` blocks), whatever its logical length.
    fn check_pool_fits(&self, id: u64, rows: usize) -> Result<()> {
        let window = self.sessions.get(&id).and_then(|e| e.session.window());
        let needed = self.pool.blocks_for_windowed(rows, window);
        if needed > self.pool.capacity() {
            return Err(Error::Coordinator(format!(
                "session {id} needs {needed} blocks for {rows} rows; the kv-cache \
                 pool holds only {} (raise num_blocks or block_size)",
                self.pool.capacity()
            )));
        }
        Ok(())
    }

    /// Restore a preempted session's cache, preempting victims outside
    /// `exclude` as needed. Defers only when no victim can free another
    /// block.
    fn ensure_resident(&mut self, id: u64, exclude: &[u64]) -> Result<()> {
        let len = self
            .sessions
            .get(&id)
            .map(|e| e.session.len())
            .ok_or_else(|| Error::Coordinator(format!("unknown decode session {id}")))?;
        self.check_pool_fits(id, len)?;
        loop {
            let entry = self.sessions.get_mut(&id).expect("checked above");
            match entry.session.restore(&mut self.pool) {
                Ok(()) => return Ok(()),
                Err(Error::AdmissionDeferred(msg)) => {
                    if !self.preempt_victim(exclude) {
                        return Err(Error::AdmissionDeferred(msg));
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Stage one step's `(k, v)` onto a session under pool pressure:
    /// restore the session if preempted, append the row, and on block
    /// exhaustion preempt victims outside `exclude` and retry. Each
    /// retry strictly frees blocks, so the loop terminates; when no
    /// victim remains the step defers for the caller to requeue.
    fn stage_with_pressure(
        &mut self,
        id: u64,
        exclude: &[u64],
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        let len = self
            .sessions
            .get(&id)
            .map(|e| e.session.len())
            .ok_or_else(|| Error::Coordinator(format!("unknown decode session {id}")))?;
        self.check_pool_fits(id, len + 1)?;
        loop {
            let entry = self.sessions.get_mut(&id).expect("checked above");
            let attempt = match entry.session.restore(&mut self.pool) {
                Ok(()) => entry.session.stage(&mut self.pool, q, k, v),
                Err(e) => Err(e),
            };
            match attempt {
                Ok(()) => return Ok(()),
                Err(Error::AdmissionDeferred(msg)) => {
                    if !self.preempt_victim(exclude) {
                        return Err(Error::AdmissionDeferred(msg));
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Run one decode step for the request's session, alone in its own
    /// engine — the standalone path waves are differentially compared
    /// against. Pool pressure behaves as in waves: victims are
    /// preempted to make room, and [`Error::AdmissionDeferred`] asks
    /// the caller to retry later.
    pub fn step(&mut self, req: DecodeStepRequest) -> Result<DecodeStepResponse> {
        let class = self.admit_step(&req)?;
        let exclude = [req.session];
        self.stage_with_pressure(req.session, &exclude, &req.q, &req.k, &req.v)?;
        let entry = self.sessions.get_mut(&req.session).expect("admitted");
        let lane = entry.lane;
        let (row, summary) = match entry.session.run_staged(&self.pool, &req.q) {
            Ok(ok) => ok,
            Err(e) => {
                // A failed step must not corrupt the session: unwind
                // the staged row so a retry sees the pre-step state.
                entry.session.unstage(&mut self.pool);
                return Err(e);
            }
        };
        entry.session.commit_row(&mut self.pool, row.clone());
        let step = (entry.session.len() - 1) as u64;
        self.steps_served += 1;
        Ok(DecodeStepResponse {
            session: req.session,
            step,
            class,
            lane,
            wave_lanes: 1,
            row,
            cycles: summary.cycles,
        })
    }

    /// Run one scheduling iteration of continuous batching: at most one
    /// step per session, all staged steps executed spatially in **one
    /// engine** (one lane scope per session, sticky lane indices), with
    /// per-request results in input order. Requests that fail admission
    /// (unknown session, sticky-class violation, context window on an
    /// unwindowed session, a duplicate session in the wave, bad
    /// shapes) error individually
    /// without disturbing the rest of the wave; requests the block pool
    /// cannot currently hold return [`Error::AdmissionDeferred`]
    /// individually for the caller to requeue. Staged block
    /// allocations are transactional: a failed wave unwinds every
    /// session's staged row (and its block, if freshly allocated).
    /// Requests are borrowed so a deferred one can be requeued by the
    /// caller without re-cloning its rows.
    pub fn step_wave(
        &mut self,
        reqs: &[DecodeStepRequest],
    ) -> Vec<Result<DecodeStepResponse>> {
        let mut results: Vec<Option<Result<DecodeStepResponse>>> =
            (0..reqs.len()).map(|_| None).collect();
        // Stage: validate each step and append its (k, v) to the
        // session's block table under pool pressure. Earlier-staged
        // wave members are protected from preemption; a session that
        // cannot get blocks defers individually.
        let mut staged: Vec<(usize, u64, DecodeClass)> = Vec::new();
        for (i, req) in reqs.iter().enumerate() {
            if staged.iter().any(|&(_, id, _)| id == req.session) {
                results[i] = Some(Err(Error::Coordinator(format!(
                    "session {} appears twice in one wave (iteration-level \
                     batching runs one step per session)",
                    req.session
                ))));
                continue;
            }
            let mut exclude: Vec<u64> = staged.iter().map(|&(_, id, _)| id).collect();
            exclude.push(req.session);
            let admitted = self.admit_step(req).and_then(|class| {
                self.stage_with_pressure(req.session, &exclude, &req.q, &req.k, &req.v)
                    .map(|()| class)
            });
            match admitted {
                Ok(class) => staged.push((i, req.session, class)),
                Err(e) => results[i] = Some(Err(e)),
            }
        }
        if !staged.is_empty() {
            // Build one engine with one decode pipeline per staged
            // session, scoped by its sticky lane; each lane's K/V rows
            // are gathered by walking the session's block table.
            let built = {
                let mut steps: Vec<LaneStepRows<'_>> = Vec::with_capacity(staged.len());
                for &(i, id, _) in &staged {
                    let entry = self.sessions.get(&id).expect("staged");
                    let view = self.pool.view(entry.session.table());
                    steps.push(LaneStepRows {
                        kind: entry.session.kind(),
                        lane: entry.lane,
                        q: &reqs[i].q,
                        keys: view.keys,
                        values: view.values,
                    });
                }
                build_decode_lanes_rows(&steps, DepthPolicy::Inferred)
            };
            let run = built.and_then(|mut pool| {
                if let Some(mode) = self.cfg.mode {
                    pool.engine.set_scheduler_mode(mode);
                }
                if let Some(th) = self.cfg.threads {
                    // One component per lane: the wave's lane-level
                    // parallelism, bit-identical for every value.
                    pool.engine.set_threads(th);
                }
                pool.run()
            });
            match run {
                Ok((mut rows, summary)) => {
                    let wave_lanes = staged.len();
                    for (j, &(i, id, class)) in staged.iter().enumerate() {
                        let entry = self.sessions.get_mut(&id).expect("staged");
                        entry.session.commit_row(&mut self.pool, rows[j].clone());
                        let lane = entry.lane;
                        let step = (entry.session.len() - 1) as u64;
                        self.steps_served += 1;
                        results[i] = Some(Ok(DecodeStepResponse {
                            session: id,
                            step,
                            class,
                            lane,
                            wave_lanes,
                            // The transcript keeps the clone above; the
                            // response takes the original row.
                            row: std::mem::take(&mut rows[j]),
                            cycles: summary.cycles,
                        }));
                    }
                }
                Err(e) => {
                    // Unwind every staged cache row (and any block it
                    // allocated): a failed wave must leave all sessions
                    // exactly as they were.
                    let msg = e.to_string();
                    for &(i, id, _) in &staged {
                        if let Some(entry) = self.sessions.get_mut(&id) {
                            entry.session.unstage(&mut self.pool);
                        }
                        results[i] = Some(Err(Error::Coordinator(format!(
                            "decode wave failed: {msg}"
                        ))));
                    }
                }
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every wave request resolved"))
            .collect()
    }

    /// Append one prompt row under pool pressure (restore + preempt +
    /// retry, as [`Self::stage_with_pressure`] does for decode steps),
    /// returning the transactional undo token the wave resolves.
    fn append_prefill_with_pressure(
        &mut self,
        id: u64,
        exclude: &[u64],
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<AppendUndo> {
        let len = self
            .sessions
            .get(&id)
            .map(|e| e.session.len())
            .ok_or_else(|| Error::Coordinator(format!("unknown decode session {id}")))?;
        self.check_pool_fits(id, len + 1)?;
        loop {
            let entry = self.sessions.get_mut(&id).expect("checked above");
            let attempt = match entry.session.restore(&mut self.pool) {
                Ok(()) => entry
                    .session
                    .append_prefill_row(&mut self.pool, k.clone(), v.clone()),
                Err(e) => Err(e),
            };
            match attempt {
                Ok(undo) => return Ok(undo),
                Err(Error::AdmissionDeferred(msg)) => {
                    if !self.preempt_victim(exclude) {
                        return Err(Error::AdmissionDeferred(msg));
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Stage one prefill grant: append the granted prompt rows under
    /// pool pressure (victims outside `exclude` may be preempted) and
    /// lay out the chunk segments the wave will run. The appends are
    /// transactional — the returned undos are committed or reverted
    /// with the wave. A grant the pool can only partially hold stages
    /// what fits; one that cannot stage anything defers.
    fn stage_prefill(
        &mut self,
        id: u64,
        exclude: &[u64],
        max_rows: usize,
        max_keys: usize,
    ) -> Result<(usize, Vec<SegPlan>, Vec<AppendUndo>)> {
        let (rows_total, mut next_row, mut kd, splittable, windowed) = {
            let entry = self
                .sessions
                .get(&id)
                .ok_or_else(|| Error::Coordinator(format!("unknown decode session {id}")))?;
            let pf = entry.prefill.as_ref().ok_or_else(|| {
                Error::Coordinator(format!("session {id} has no pending prefill"))
            })?;
            (
                pf.k.len(),
                pf.next_row,
                pf.keys_done,
                entry.session.kind() == DecodeKind::MemoryFree
                    && entry.session.window().is_none(),
                entry.session.window().is_some(),
            )
        };
        // A windowed ring evicts in place, so a second row staged in
        // the same wave could overwrite rows the first row's gather
        // still needs: windowed prompts ingest one whole row per wave.
        let max_rows = if windowed { max_rows.min(1) } else { max_rows };
        let mut segs: Vec<SegPlan> = Vec::new();
        let mut undos: Vec<AppendUndo> = Vec::new();
        let mut keys_left = max_keys;
        while next_row < rows_total && segs.len() < max_rows {
            let first = segs.is_empty();
            let rem = (next_row + 1) - kd;
            let take = if keys_left >= rem {
                rem
            } else if splittable && keys_left > 0 {
                keys_left
            } else if first {
                // Progress guarantee: a planner min-grant can round
                // below one whole row; the first segment runs anyway —
                // whole for a non-splittable row, one key otherwise.
                if splittable {
                    1
                } else {
                    rem
                }
            } else {
                break;
            };
            if kd == 0 {
                // First segment of the row: its (k, v) enters the cache.
                let (k, v) = {
                    let entry = self.sessions.get(&id).expect("checked above");
                    let pf = entry.prefill.as_ref().expect("checked above");
                    (pf.k[next_row].clone(), pf.v[next_row].clone())
                };
                match self.append_prefill_with_pressure(id, exclude, k, v) {
                    Ok(undo) => undos.push(undo),
                    Err(Error::AdmissionDeferred(msg)) => {
                        if segs.is_empty() {
                            return Err(Error::AdmissionDeferred(msg));
                        }
                        // Keep the rows that did fit; the rest waits.
                        return Ok((rows_total, segs, undos));
                    }
                    Err(e) => {
                        // Hard failure: unwind this grant's appends.
                        let entry = self.sessions.get_mut(&id).expect("checked above");
                        for undo in undos.into_iter().rev() {
                            entry.session.undo_prefill_append(&mut self.pool, undo);
                        }
                        return Err(e);
                    }
                }
            }
            let finalize = kd + take == next_row + 1;
            segs.push(SegPlan {
                row: next_row,
                kd,
                take,
                finalize,
            });
            keys_left = keys_left.saturating_sub(take);
            if finalize {
                next_row += 1;
                kd = 0;
            } else {
                // A mid-row stop ends the grant (the carry resumes it).
                break;
            }
        }
        Ok((rows_total, segs, undos))
    }

    /// Run one **mixed** scheduling wave: decode steps and
    /// chunked-prefill grants staged together — transactionally, like
    /// [`Self::step_wave`] — and executed spatially in one engine
    /// (step lanes exactly as in a decode wave; prefill segments as
    /// seeded-scan chunk pipelines beside them, see
    /// [`build_mixed_wave`]). Per-request results arrive in input
    /// order: bad requests error individually, pool exhaustion defers
    /// individually (a partially satisfiable grant stages what fits),
    /// and a failed engine run unwinds every staged row and append.
    /// Prefill cursors and carries advance only on success, so a failed
    /// wave leaves every session bit-exactly as it was.
    pub fn wave(&mut self, reqs: &[WaveRequest]) -> Vec<Result<WaveOutcome>> {
        let mut results: Vec<Option<Result<WaveOutcome>>> =
            (0..reqs.len()).map(|_| None).collect();
        let mut staged: Vec<StagedItem> = Vec::new();
        let mut staged_ids: Vec<u64> = Vec::new();
        for (i, wr) in reqs.iter().enumerate() {
            let id = wr.session();
            if staged_ids.contains(&id) {
                results[i] = Some(Err(Error::Coordinator(format!(
                    "session {id} appears twice in one wave (iteration-level \
                     batching runs one grant per session)"
                ))));
                continue;
            }
            let mut exclude = staged_ids.clone();
            exclude.push(id);
            match wr {
                WaveRequest::Step(req) => {
                    let admitted = self.admit_step(req).and_then(|class| {
                        self.stage_with_pressure(id, &exclude, &req.q, &req.k, &req.v)
                            .map(|()| class)
                    });
                    match admitted {
                        Ok(class) => {
                            staged_ids.push(id);
                            staged.push(StagedItem::Step { i, id, class });
                        }
                        Err(e) => results[i] = Some(Err(e)),
                    }
                }
                WaveRequest::Prefill {
                    max_rows, max_keys, ..
                } => match self.stage_prefill(id, &exclude, *max_rows, *max_keys) {
                    Ok((rows_total, segs, undos)) if !segs.is_empty() => {
                        staged_ids.push(id);
                        staged.push(StagedItem::Prefill {
                            i,
                            id,
                            rows_total,
                            segs,
                            undos,
                        });
                    }
                    Ok(_) => {
                        results[i] = Some(Err(Error::Coordinator(format!(
                            "empty prefill grant for session {id}"
                        ))));
                    }
                    Err(e) => results[i] = Some(Err(e)),
                },
            }
        }
        if !staged.is_empty() {
            // Build one engine: decode steps in their lane scopes,
            // prefill segments as chunk pipelines beside them. Key
            // spans come from prefix gathers so a row staged for a
            // later segment never leaks into an earlier row's view.
            let built = {
                let mut work: Vec<LaneWork<'_>> = Vec::new();
                for item in &staged {
                    match item {
                        StagedItem::Step { i, id, .. } => {
                            let entry = self.sessions.get(id).expect("staged");
                            let view = self.pool.view(entry.session.table());
                            let WaveRequest::Step(req) = &reqs[*i] else {
                                unreachable!("step item indexes a step request")
                            };
                            work.push(LaneWork::Step(LaneStepRows {
                                kind: entry.session.kind(),
                                lane: entry.lane,
                                q: &req.q,
                                keys: view.keys,
                                values: view.values,
                            }));
                        }
                        StagedItem::Prefill { id, segs, .. } => {
                            let entry = self.sessions.get(id).expect("staged");
                            let pf = entry.prefill.as_ref().expect("staged prefill");
                            for (j, seg) in segs.iter().enumerate() {
                                let (keys, values) = if entry.session.window().is_some() {
                                    // One whole row per wave: the ring
                                    // gather is exactly a decode step's.
                                    let view = self.pool.view(entry.session.table());
                                    (view.keys, view.values)
                                } else {
                                    let view = self
                                        .pool
                                        .view_prefix(entry.session.table(), seg.row + 1);
                                    (
                                        view.keys[seg.kd..seg.kd + seg.take].to_vec(),
                                        view.values[seg.kd..seg.kd + seg.take].to_vec(),
                                    )
                                };
                                let carry = if seg.kd == 0 {
                                    SoftmaxCarry::fresh(entry.class.d)
                                } else {
                                    pf.carry.clone()
                                };
                                work.push(LaneWork::Chunk(LaneChunkRows {
                                    kind: entry.session.kind(),
                                    lane: entry.lane,
                                    seg: j,
                                    q: &pf.q[seg.row],
                                    keys,
                                    values,
                                    carry,
                                    finalize: seg.finalize,
                                }));
                            }
                        }
                    }
                }
                build_mixed_wave(&work, DepthPolicy::Inferred)
            };
            let run = built.and_then(|mut wave| {
                if let Some(mode) = self.cfg.mode {
                    wave.engine.set_scheduler_mode(mode);
                }
                if let Some(th) = self.cfg.threads {
                    wave.engine.set_threads(th);
                }
                wave.run()
            });
            match run {
                Ok((mut rows, summary)) => {
                    let wave_lanes = staged.len();
                    let mut cursor = 0usize;
                    for item in staged {
                        match item {
                            StagedItem::Step { i, id, class } => {
                                let row = std::mem::take(&mut rows[cursor]);
                                cursor += 1;
                                let entry = self.sessions.get_mut(&id).expect("staged");
                                entry.session.commit_row(&mut self.pool, row.clone());
                                let lane = entry.lane;
                                let step = (entry.session.len() - 1) as u64;
                                self.steps_served += 1;
                                results[i] = Some(Ok(WaveOutcome::Step(DecodeStepResponse {
                                    session: id,
                                    step,
                                    class,
                                    lane,
                                    wave_lanes,
                                    row,
                                    cycles: summary.cycles,
                                })));
                            }
                            StagedItem::Prefill {
                                i,
                                id,
                                rows_total,
                                segs,
                                undos,
                            } => {
                                let seg_rows: Vec<Vec<f32>> = rows
                                    [cursor..cursor + segs.len()]
                                    .iter_mut()
                                    .map(std::mem::take)
                                    .collect();
                                cursor += segs.len();
                                for undo in undos {
                                    self.pool.commit_append(undo);
                                }
                                let entry = self.sessions.get_mut(&id).expect("staged");
                                let d = entry.class.d;
                                for (seg, row) in segs.iter().zip(seg_rows) {
                                    let pf =
                                        entry.prefill.as_mut().expect("staged prefill");
                                    if seg.finalize {
                                        pf.next_row = seg.row + 1;
                                        pf.keys_done = 0;
                                        pf.carry = SoftmaxCarry::fresh(d);
                                        entry.session.push_output_row(row);
                                        self.steps_served += 1;
                                    } else {
                                        pf.keys_done = seg.kd + seg.take;
                                        pf.carry = SoftmaxCarry::unpack(&row)
                                            .expect("carry rows hold m, r and ℓ⃗");
                                    }
                                }
                                let rows_done = entry
                                    .prefill
                                    .as_ref()
                                    .map(|pf| pf.next_row)
                                    .unwrap_or(rows_total);
                                let done = rows_done >= rows_total;
                                if done {
                                    entry.prefill = None;
                                }
                                let lane = entry.lane;
                                results[i] =
                                    Some(Ok(WaveOutcome::Prefill(PrefillProgress {
                                        session: id,
                                        rows_done,
                                        rows_total,
                                        done,
                                        lane,
                                        wave_lanes,
                                        cycles: summary.cycles,
                                    })));
                            }
                        }
                    }
                }
                Err(e) => {
                    // Unwind everything in reverse staging order: no
                    // prefill cursor moved yet, so reverting rows and
                    // appends restores the exact pre-wave state.
                    let msg = e.to_string();
                    for item in staged.into_iter().rev() {
                        match item {
                            StagedItem::Step { i, id, .. } => {
                                if let Some(entry) = self.sessions.get_mut(&id) {
                                    entry.session.unstage(&mut self.pool);
                                }
                                results[i] = Some(Err(Error::Coordinator(format!(
                                    "decode wave failed: {msg}"
                                ))));
                            }
                            StagedItem::Prefill { i, id, undos, .. } => {
                                if let Some(entry) = self.sessions.get_mut(&id) {
                                    for undo in undos.into_iter().rev() {
                                        entry
                                            .session
                                            .undo_prefill_append(&mut self.pool, undo);
                                    }
                                }
                                results[i] = Some(Err(Error::Coordinator(format!(
                                    "decode wave failed: {msg}"
                                ))));
                            }
                        }
                    }
                }
            }
        }
        results
            .into_iter()
            .map(|r| r.expect("every wave request resolved"))
            .collect()
    }

    /// Retire a session, returning its output transcript (one row per
    /// decoded token), or `None` if the id is unknown. The session's
    /// lane and block references are reclaimed for the next admission
    /// (shared blocks free once their last referencing session closes).
    pub fn close(&mut self, id: u64) -> Option<Matrix> {
        let entry = self.sessions.remove(&id)?;
        self.lane_owner[entry.lane] = None;
        Some(entry.session.close(&mut self.pool))
    }

    /// Number of open sessions.
    pub fn active(&self) -> usize {
        self.sessions.len()
    }

    /// Total steps served across all sessions (monotonic).
    pub fn steps_served(&self) -> u64 {
        self.steps_served
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::decode::{decode_workload, DecodeSession};
    use crate::attention::reference::{assert_close, sdpa_online_f32_masked};
    use crate::attention::workload::{Mask, Workload};

    fn req(session: u64, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> DecodeStepRequest {
        DecodeStepRequest { session, q, k, v }
    }

    fn wreq(w: &Workload, session: u64, t: usize) -> DecodeStepRequest {
        req(session, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
    }

    #[test]
    fn open_step_close_roundtrip_matches_causal_reference() {
        let w = Workload::random(6, 4, 0x5E55);
        let mut table = SessionTable::new(SessionConfig::default()).unwrap();
        let id = table.open(4).unwrap();
        for t in 0..w.n {
            let resp = table.step(wreq(&w, id, t)).unwrap();
            assert_eq!(resp.session, id);
            assert_eq!(resp.step, t as u64, "per-session step counter");
            assert_eq!(resp.class, DecodeClass { d: 4 });
            assert_eq!(resp.lane, 0, "first session takes lane 0");
            assert_eq!(resp.wave_lanes, 1, "standalone step runs alone");
            assert!(resp.cycles > 0);
        }
        assert_eq!(table.len_of(id), Some(w.n));
        let transcript = table.close(id).unwrap();
        assert_close(
            &transcript,
            &sdpa_online_f32_masked(&w, &Mask::Causal),
            1e-6,
            "session transcript vs causal reference",
        );
        assert_eq!(table.active(), 0);
        assert_eq!(table.lanes_in_use(), 0, "lane reclaimed on close");
        assert_eq!(table.pool_used_blocks(), 0, "blocks reclaimed on close");
        assert_eq!(table.steps_served(), w.n as u64);
    }

    #[test]
    fn sticky_routing_rejects_class_changes() {
        let mut table = SessionTable::new(SessionConfig::default()).unwrap();
        let id = table.open(4).unwrap();
        assert_eq!(table.class_of(id), Some(DecodeClass { d: 4 }));
        let err = table.step(req(id, vec![0.0; 8], vec![0.0; 8], vec![0.0; 8]));
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("sticky routing")),
            "a d=8 step must not land on a d=4 session"
        );
        // The rejected step left the session untouched.
        assert_eq!(table.len_of(id), Some(0));
    }

    #[test]
    fn interleaved_ragged_sessions_stay_independent() {
        // Three sessions of different lengths, steps interleaved — the
        // ragged-batch serving shape. Each transcript must match the
        // causal reference of its own (truncated) workload.
        let lens = [1usize, 3, 5];
        let ws: Vec<Workload> = lens
            .iter()
            .map(|&l| Workload::random(l, 4, 0x1000 + l as u64))
            .collect();
        let mut table = SessionTable::new(SessionConfig::default()).unwrap();
        let ids: Vec<u64> = ws.iter().map(|_| table.open(4).unwrap()).collect();
        let max_len = *lens.iter().max().unwrap();
        for t in 0..max_len {
            for (s, w) in ws.iter().enumerate() {
                if t < w.n {
                    let resp = table.step(wreq(w, ids[s], t)).unwrap();
                    assert_eq!(resp.step, t as u64, "session {s} counter");
                }
            }
        }
        for (s, w) in ws.iter().enumerate() {
            let transcript = table.close(ids[s]).unwrap();
            assert_close(
                &transcript,
                &sdpa_online_f32_masked(w, &Mask::Causal),
                1e-6,
                &format!("interleaved session {s}"),
            );
        }
    }

    #[test]
    fn admission_control_and_context_window() {
        let mut table = SessionTable::new(SessionConfig {
            kind: DecodeKind::MemoryFree,
            max_sessions: 2,
            max_len: 2,
            ..SessionConfig::default()
        })
        .unwrap();
        let a = table.open(2).unwrap();
        let _b = table.open(2).unwrap();
        // Admission at capacity is *deferred* (the typed retry signal),
        // not hard-refused — the requeue-path bugfix.
        assert!(matches!(
            table.open(2),
            Err(Error::AdmissionDeferred(msg)) if msg.contains("session table full")
        ));
        // Free a slot and re-admit.
        assert!(table.close(a).is_some());
        let c = table.open(2).unwrap();
        // Context window: third step must be rejected (hard — retrying
        // cannot shrink a session).
        for _ in 0..2 {
            table
                .step(req(c, vec![0.1, 0.2], vec![0.3, 0.4], vec![0.5, 0.6]))
                .unwrap();
        }
        let err = table.step(req(c, vec![0.1, 0.2], vec![0.3, 0.4], vec![0.5, 0.6]));
        assert!(matches!(err, Err(Error::Coordinator(msg)) if msg.contains("context window")));
    }

    #[test]
    fn windowed_sessions_outlive_max_len_in_a_bounded_ring() {
        // A window-3 session decodes 4× the table's `max_len` while its
        // ring never exceeds ⌈3/2⌉ = 2 blocks, and the transcript stays
        // bit-identical to the contiguous windowed chain.
        let n = 32;
        let w = Workload::random(n, 4, 0x317D0);
        let mut table = SessionTable::new(SessionConfig {
            max_len: 8,
            kv: KvCacheConfig {
                block_size: 2,
                num_blocks: 4,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let id = table.open_windowed(4, 3).unwrap();
        assert_eq!(table.window_of(id), Some(Some(3)));
        for t in 0..n {
            let resp = table.step(wreq(&w, id, t)).unwrap();
            assert_eq!(resp.step, t as u64, "max_len must not apply");
            assert!(
                table.blocks_of(id).unwrap() <= 2,
                "step {t}: the ring holds at most ⌈W/block_size⌉ blocks"
            );
        }
        assert!(table.pool_evictions() > 0, "the ring recycled rows");
        let transcript = table.close(id).unwrap();
        let mut solo = DecodeSession::new_windowed(DecodeKind::MemoryFree, 4, 3);
        for t in 0..n {
            solo.step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        assert_eq!(
            &transcript,
            solo.outputs(),
            "windowed paged transcript ≡ contiguous windowed chain bitwise"
        );
        assert_eq!(table.pool_used_blocks(), 0, "ring blocks reclaimed");
        assert!(table.open_windowed(4, 0).is_err(), "window 0 rejected");
    }

    #[test]
    fn lane_pool_admission_and_lowest_lane_reclamation() {
        let mut table = SessionTable::new(SessionConfig {
            lanes: 3,
            ..SessionConfig::default()
        })
        .unwrap();
        let a = table.open(2).unwrap();
        let b = table.open(2).unwrap();
        let c = table.open(2).unwrap();
        assert_eq!(
            (table.lane_of(a), table.lane_of(b), table.lane_of(c)),
            (Some(0), Some(1), Some(2))
        );
        // Pool exhausted: admission defers on lanes even though
        // max_sessions (64) has room.
        let err = table.open(2);
        assert!(
            matches!(err, Err(Error::AdmissionDeferred(msg)) if msg.contains("no free lane"))
        );
        // Eviction-on-close reclaims the lane; reuse is lowest-first.
        table.close(b).unwrap();
        assert_eq!(table.lanes_in_use(), 2);
        let d = table.open(2).unwrap();
        assert_eq!(table.lane_of(d), Some(1), "freed lane 1 reused");
        for id in [a, c, d] {
            table.close(id).unwrap();
        }
        assert_eq!(table.lanes_in_use(), 0, "no lane leaked");
    }

    #[test]
    fn wave_transcripts_are_bit_identical_to_solo_sessions() {
        // The continuous-batching core guarantee, at the table level:
        // stepping sessions in waves (over the paged cache) yields
        // transcripts bitwise equal to stepping each session alone on
        // the *contiguous* DecodeSession — the paged-vs-contiguous
        // differential in one assert.
        let lens = [2usize, 5, 3, 4];
        let ws: Vec<Workload> = lens
            .iter()
            .enumerate()
            .map(|(i, &l)| Workload::random(l, 4, 0x2000 + i as u64))
            .collect();
        let mut table = SessionTable::new(SessionConfig {
            lanes: 4,
            kv: KvCacheConfig {
                block_size: 2,
                num_blocks: 32,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let ids: Vec<u64> = ws.iter().map(|_| table.open(4).unwrap()).collect();
        let max_len = *lens.iter().max().unwrap();
        for t in 0..max_len {
            let reqs: Vec<DecodeStepRequest> = ws
                .iter()
                .enumerate()
                .filter(|(_, w)| t < w.n)
                .map(|(s, w)| wreq(w, ids[s], t))
                .collect();
            let expect_lanes = reqs.len();
            for res in table.step_wave(&reqs) {
                let resp = res.unwrap();
                assert_eq!(resp.step, t as u64);
                assert_eq!(resp.wave_lanes, expect_lanes, "all lanes co-scheduled");
            }
        }
        for (s, w) in ws.iter().enumerate() {
            let transcript = table.close(ids[s]).unwrap();
            let mut solo = DecodeSession::new(DecodeKind::MemoryFree, w.d);
            for t in 0..w.n {
                solo.step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                    .unwrap();
            }
            assert_eq!(
                &transcript,
                solo.outputs(),
                "session {s}: paged wave transcript ≡ contiguous solo transcript bitwise"
            );
        }
        assert_eq!(table.pool_used_blocks(), 0, "all blocks reclaimed");
    }

    #[test]
    fn wave_rejects_bad_requests_individually() {
        let w = Workload::random(3, 4, 0x3000);
        let mut table = SessionTable::new(SessionConfig {
            lanes: 4,
            max_len: 2,
            ..SessionConfig::default()
        })
        .unwrap();
        let id = table.open(4).unwrap();
        // Wave: one good step, one unknown session, one duplicate of
        // the good session, one shape mismatch for a second session.
        let id2 = table.open(2).unwrap();
        let reqs = vec![
            wreq(&w, id, 0),
            req(99, vec![0.0; 4], vec![0.0; 4], vec![0.0; 4]),
            wreq(&w, id, 1),
            req(id2, vec![0.0; 4], vec![0.0; 4], vec![0.0; 4]),
        ];
        let results = table.step_wave(&reqs);
        assert!(results[0].is_ok(), "good step survives bad neighbours");
        assert!(
            matches!(&results[1], Err(Error::Coordinator(m)) if m.contains("unknown")),
            "unknown session"
        );
        assert!(
            matches!(&results[2], Err(Error::Coordinator(m)) if m.contains("twice")),
            "duplicate session in wave"
        );
        assert!(
            matches!(&results[3], Err(Error::Coordinator(m)) if m.contains("sticky")),
            "shape mismatch vs sticky class"
        );
        assert_eq!(table.len_of(id), Some(1), "only the good step landed");
        assert_eq!(table.len_of(id2), Some(0));
        // Context window applies to waves too.
        let r = table.step_wave(&[wreq(&w, id, 1)]);
        assert!(r[0].is_ok());
        let r = table.step_wave(&[wreq(&w, id, 2)]);
        assert!(
            matches!(&r[0], Err(Error::Coordinator(m)) if m.contains("context window"))
        );
    }

    #[test]
    fn heterogeneous_wave_mixes_head_dimensions_and_lengths() {
        // Lanes differ in both d and cache length — the case the old
        // multihead builder panicked on must *work* end to end.
        let wa = Workload::random(4, 2, 0x4000);
        let wb = Workload::random(2, 6, 0x4001);
        let mut table = SessionTable::new(SessionConfig {
            lanes: 2,
            ..SessionConfig::default()
        })
        .unwrap();
        let a = table.open(2).unwrap();
        let b = table.open(6).unwrap();
        // Advance a by two solo steps so the wave sees different lens.
        table.step(wreq(&wa, a, 0)).unwrap();
        table.step(wreq(&wa, a, 1)).unwrap();
        let results = table.step_wave(&[wreq(&wa, a, 2), wreq(&wb, b, 0)]);
        for r in &results {
            assert!(r.is_ok(), "heterogeneous wave must be Ok: {r:?}");
        }
        assert_eq!(results[0].as_ref().unwrap().step, 2);
        assert_eq!(results[1].as_ref().unwrap().step, 0);
        assert_eq!(table.len_of(a), Some(3));
        assert_eq!(table.len_of(b), Some(1));
    }

    #[test]
    fn degenerate_config_is_an_err_not_a_panic() {
        for bad in [
            SessionConfig { lanes: 0, ..SessionConfig::default() },
            SessionConfig { max_sessions: 0, ..SessionConfig::default() },
            SessionConfig { max_len: 0, ..SessionConfig::default() },
            SessionConfig {
                kv: KvCacheConfig {
                    block_size: 0,
                    num_blocks: 4,
                },
                ..SessionConfig::default()
            },
        ] {
            assert!(
                matches!(SessionTable::new(bad), Err(Error::Coordinator(_))),
                "config {bad:?} must be rejected"
            );
        }
    }

    #[test]
    fn unknown_sessions_and_zero_d_rejected() {
        let mut table = SessionTable::new(SessionConfig::default()).unwrap();
        assert!(table.open(0).is_err());
        let err = table.step(req(99, vec![0.0], vec![0.0], vec![0.0]));
        assert!(matches!(err, Err(Error::Coordinator(msg)) if msg.contains("unknown")));
        assert!(table.close(99).is_none());
        assert_eq!(table.class_of(99), None);
        assert_eq!(table.lane_of(99), None);
        assert!(matches!(
            table.fork(99),
            Err(Error::Coordinator(msg)) if msg.contains("unknown")
        ));
    }

    #[test]
    fn forked_sessions_share_prefix_blocks_exactly() {
        // The acceptance shape: two sessions forked from a common M-row
        // prefix consume M/block_size shared blocks + 2 private tails.
        let m = 4;
        let bs = 2;
        let w = Workload::random(m + 1, 4, 0xF0A1);
        let mut table = SessionTable::new(SessionConfig {
            lanes: 4,
            kv: KvCacheConfig {
                block_size: bs,
                num_blocks: 16,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let parent = table.open(4).unwrap();
        for t in 0..m {
            table.step(wreq(&w, parent, t)).unwrap();
        }
        let a = table.fork(parent).unwrap();
        let b = table.fork(parent).unwrap();
        assert_eq!(table.len_of(a), Some(m), "fork sees the shared prefix");
        assert_eq!(table.class_of(a), Some(DecodeClass { d: 4 }));
        // Retire the parent; the children keep the prefix alive.
        let parent_transcript = table.close(parent).unwrap();
        assert_eq!(parent_transcript.len(), m);
        assert_eq!(
            table.pool_used_blocks(),
            m / bs,
            "fork shares, it does not copy"
        );
        assert_eq!(table.pool_shared_blocks(), m / bs);
        // Each child decodes one token past the prefix → one private
        // tail block each.
        let ra = table.step(wreq(&w, a, m)).unwrap();
        let rb = table.step(wreq(&w, b, m)).unwrap();
        assert_eq!(ra.step, m as u64, "child steps continue past the prefix");
        assert_eq!(
            table.pool_used_blocks(),
            m / bs + 2,
            "M/block_size shared blocks + 2 private tails"
        );
        assert_eq!(table.pool_shared_blocks(), m / bs);
        // Both children computed the same continuation row, and it is
        // bitwise the contiguous chain's row m.
        let baseline = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        assert_eq!(ra.row, baseline[m], "forked row ≡ contiguous chain row");
        assert_eq!(rb.row, baseline[m]);
        table.close(a).unwrap();
        table.close(b).unwrap();
        assert_eq!(table.pool_used_blocks(), 0, "last close frees the prefix");
    }

    #[test]
    fn pool_pressure_preempts_and_transcripts_stay_bit_identical() {
        // Pool of 4 single-row blocks, two sessions needing 4 + 2 rows:
        // serving them interleaved forces preemption, and every
        // transcript must still equal the unpressured contiguous run.
        let wa = Workload::random(4, 4, 0x9E5501);
        let wb = Workload::random(2, 4, 0x9E5502);
        let mut table = SessionTable::new(SessionConfig {
            lanes: 2,
            kv: KvCacheConfig {
                block_size: 1,
                num_blocks: 4,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let a = table.open(4).unwrap();
        let b = table.open(4).unwrap();
        for t in 0..3 {
            table.step(wreq(&wa, a, t)).unwrap();
        }
        table.step(wreq(&wb, b, 0)).unwrap(); // pool now full (3 + 1)
        assert_eq!(table.pool_free_blocks(), 0);
        // a's 4th row has no block: b (1 exclusive block) is preempted.
        table.step(wreq(&wa, a, 3)).unwrap();
        assert_eq!(table.is_preempted(b), Some(true), "b swapped out");
        assert!(table.preemptions() >= 1);
        // b's next step restores it (preempting a in turn).
        table.step(wreq(&wb, b, 1)).unwrap();
        assert_eq!(table.is_preempted(a), Some(true), "a swapped out");
        assert_eq!(table.len_of(b), Some(2));
        let ta = table.close(a).unwrap();
        let tb = table.close(b).unwrap();
        assert_eq!(
            ta,
            decode_workload(DecodeKind::MemoryFree, &wa).unwrap(),
            "preempted session a ≡ unpressured chain bitwise"
        );
        assert_eq!(
            tb,
            decode_workload(DecodeKind::MemoryFree, &wb).unwrap(),
            "preempted session b ≡ unpressured chain bitwise"
        );
        assert_eq!(table.pool_used_blocks(), 0);
    }

    #[test]
    fn a_session_larger_than_the_pool_is_a_hard_error() {
        let w = Workload::random(3, 2, 0xCAFE);
        let mut table = SessionTable::new(SessionConfig {
            kv: KvCacheConfig {
                block_size: 1,
                num_blocks: 2,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let id = table.open(2).unwrap();
        table.step(wreq(&w, id, 0)).unwrap();
        table.step(wreq(&w, id, 1)).unwrap();
        // Row 3 can never fit a 2-block pool: deferring would retry
        // forever, so this is a hard Coordinator error.
        let err = table.step(wreq(&w, id, 2));
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("pool")),
            "oversized session must hard-fail, not defer"
        );
        assert_eq!(table.len_of(id), Some(2), "failed step did not stage");
    }

    #[test]
    fn wave_under_pool_pressure_defers_individually_and_recovers() {
        // Two sessions whose joint demand exceeds the pool, stepped in
        // waves: each wave completes at least one step (the other
        // defers), and alternating priority lets both finish with
        // bit-identical transcripts.
        let wa = Workload::random(3, 4, 0x9E5503);
        let wb = Workload::random(3, 4, 0x9E5504);
        let mut table = SessionTable::new(SessionConfig {
            lanes: 2,
            kv: KvCacheConfig {
                block_size: 1,
                num_blocks: 3,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let a = table.open(4).unwrap();
        let b = table.open(4).unwrap();
        let mut ta = 0usize;
        let mut tb = 0usize;
        let mut deferred_first: Option<u64> = None;
        let mut guard = 0;
        while ta < wa.n || tb < wb.n {
            guard += 1;
            assert!(guard < 50, "pressure waves must make progress");
            let mut reqs = Vec::new();
            // Deferred-session-first ordering (what the server does).
            let order: Vec<(u64, &Workload, &mut usize)> = if deferred_first == Some(b) {
                vec![(b, &wb, &mut tb), (a, &wa, &mut ta)]
            } else {
                vec![(a, &wa, &mut ta), (b, &wb, &mut tb)]
            };
            let mut cursors = Vec::new();
            for (id, w, t) in order {
                if *t < w.n {
                    reqs.push(wreq(w, id, *t));
                    cursors.push((id, t));
                }
            }
            if reqs.is_empty() {
                break;
            }
            let results = table.step_wave(&reqs);
            deferred_first = None;
            for (res, (id, t)) in results.into_iter().zip(cursors) {
                match res {
                    Ok(_) => *t += 1,
                    Err(Error::AdmissionDeferred(_)) => deferred_first = Some(id),
                    Err(e) => panic!("unexpected wave error: {e}"),
                }
            }
        }
        assert!(table.preemptions() > 0, "pressure must have preempted");
        let ta = table.close(a).unwrap();
        let tb = table.close(b).unwrap();
        assert_eq!(ta, decode_workload(DecodeKind::MemoryFree, &wa).unwrap());
        assert_eq!(tb, decode_workload(DecodeKind::MemoryFree, &wb).unwrap());
    }

    fn prompt_of(w: &Workload, rows: usize) -> PrefillPrompt {
        PrefillPrompt {
            q: w.q[..rows].to_vec(),
            k: w.k[..rows].to_vec(),
            v: w.v[..rows].to_vec(),
        }
    }

    #[test]
    fn chunked_prefill_transcripts_match_the_solo_chain_bitwise() {
        // A 5-row prompt ingested in chunks of ≤ 2 rows / ≤ 3 keys —
        // forcing mid-row splits with carry resume — then 3 decode
        // steps. The transcript must equal the unchunked oracle chain
        // to the bit.
        let w = Workload::random(8, 4, 0xC0DE);
        let mut table = SessionTable::new(SessionConfig {
            kind: DecodeKind::MemoryFree,
            kv: KvCacheConfig {
                block_size: 2,
                num_blocks: 32,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let id = table
            .open_with_spec(4, None, Priority::Interactive, Some(prompt_of(&w, 5)))
            .unwrap();
        assert_eq!(table.priority_of(id), Some(Priority::Interactive));
        assert_eq!(table.prefill_remaining(id), Some(5));
        // Decode steps and forks must wait for the prompt.
        let err = table.step(wreq(&w, id, 5));
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("prefill")),
            "decode before prefill completes must be rejected"
        );
        let err = table.fork(id);
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("prefill")),
            "fork before prefill completes must be rejected"
        );
        let mut waves = 0;
        while table.prefill_remaining(id) != Some(0) {
            waves += 1;
            assert!(waves < 20, "prefill must make progress every wave");
            let res = table.wave(&[WaveRequest::Prefill {
                session: id,
                max_rows: 2,
                max_keys: 3,
            }]);
            let Ok(WaveOutcome::Prefill(p)) = &res[0] else {
                panic!("prefill grant failed: {:?}", res[0]);
            };
            assert_eq!(p.session, id);
            assert_eq!(p.rows_total, 5);
        }
        assert!(waves > 2, "3-key grants cannot swallow 5 rows in 2 waves");
        assert_eq!(table.prefill_remaining(id), Some(0));
        assert_eq!(table.len_of(id), Some(5), "all 5 prompt rows cached");
        for t in 5..w.n {
            table.step(wreq(&w, id, t)).unwrap();
        }
        let transcript = table.close(id).unwrap();
        assert_eq!(
            transcript,
            decode_workload(DecodeKind::MemoryFree, &w).unwrap(),
            "chunked prefill + decode must be bit-identical to the solo chain"
        );
    }

    #[test]
    fn mixed_waves_run_decode_beside_chunked_prefill() {
        // One session decodes while another ingests its prompt in the
        // same waves; both transcripts must match their solo oracles.
        let wd = Workload::random(4, 4, 0x30A1);
        let wp = Workload::random(6, 4, 0x30A2);
        let mut table = SessionTable::new(SessionConfig::default()).unwrap();
        let a = table.open(4).unwrap();
        table.step(wreq(&wd, a, 0)).unwrap();
        let b = table
            .open_with_spec(4, None, Priority::Bulk, Some(prompt_of(&wp, 6)))
            .unwrap();
        for t in 1..wd.n {
            let res = table.wave(&[
                WaveRequest::Step(wreq(&wd, a, t)),
                WaveRequest::Prefill {
                    session: b,
                    max_rows: 2,
                    max_keys: 4,
                },
            ]);
            assert!(
                matches!(&res[0], Ok(WaveOutcome::Step(_))),
                "{:?}",
                res[0]
            );
            assert!(
                matches!(&res[1], Ok(WaveOutcome::Prefill(_))),
                "{:?}",
                res[1]
            );
        }
        let mut guard = 0;
        while table.prefill_remaining(b) != Some(0) {
            guard += 1;
            assert!(guard < 20, "prefill drain stalled");
            let res = table.wave(&[WaveRequest::Prefill {
                session: b,
                max_rows: 2,
                max_keys: 4,
            }]);
            assert!(res[0].is_ok(), "{:?}", res[0]);
        }
        let ta = table.close(a).unwrap();
        let tb = table.close(b).unwrap();
        assert_eq!(ta, decode_workload(DecodeKind::MemoryFree, &wd).unwrap());
        assert_eq!(tb, decode_workload(DecodeKind::MemoryFree, &wp).unwrap());
    }

    #[test]
    fn windowed_prompts_ingest_one_row_per_wave_bitwise() {
        // A ring evicts in place, so windowed prompts are
        // non-splittable and capped at one row per wave regardless of
        // the grant — and still land bit-identical to the contiguous
        // windowed chain.
        let n = 7;
        let w = Workload::random(n, 4, 0x317D1);
        let mut table = SessionTable::new(SessionConfig {
            kind: DecodeKind::MemoryFree,
            kv: KvCacheConfig {
                block_size: 2,
                num_blocks: 8,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let id = table
            .open_with_spec(4, Some(3), Priority::Standard, Some(prompt_of(&w, n)))
            .unwrap();
        let mut waves = 0;
        while let Some((total, next, kd, splittable)) = table.prefill_state(id) {
            assert!(!splittable, "windowed rows never split");
            assert_eq!(kd, 0, "windowed prefill has no mid-row carry");
            let res = table.wave(&[WaveRequest::Prefill {
                session: id,
                max_rows: 4,
                max_keys: 100,
            }]);
            let Ok(WaveOutcome::Prefill(p)) = &res[0] else {
                panic!("windowed grant failed: {:?}", res[0]);
            };
            assert_eq!(p.rows_done, next + 1, "exactly one row per wave");
            assert_eq!(p.rows_total, total);
            waves += 1;
            assert!(waves <= n, "too many waves");
        }
        assert_eq!(waves, n, "one wave per prompt row");
        let transcript = table.close(id).unwrap();
        let mut solo = DecodeSession::new_windowed(DecodeKind::MemoryFree, 4, 3);
        for t in 0..n {
            solo.step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        assert_eq!(
            transcript,
            *solo.outputs(),
            "windowed chunked prefill vs solo windowed chain"
        );
    }

    #[test]
    fn preemption_prefers_lower_priority_victims() {
        // Pool pressure must evict the Bulk resident before the
        // Interactive one, whatever their block counts say.
        let wa = Workload::random(2, 4, 0x9B01);
        let wb = Workload::random(2, 4, 0x9B02);
        let wc = Workload::random(1, 4, 0x9B03);
        let mut table = SessionTable::new(SessionConfig {
            kind: DecodeKind::MemoryFree,
            lanes: 3,
            kv: KvCacheConfig {
                block_size: 1,
                num_blocks: 4,
            },
            ..SessionConfig::default()
        })
        .unwrap();
        let hi = table
            .open_with_spec(4, None, Priority::Interactive, None)
            .unwrap();
        let lo = table
            .open_with_spec(4, None, Priority::Bulk, None)
            .unwrap();
        for t in 0..2 {
            table.step(wreq(&wa, hi, t)).unwrap();
            table.step(wreq(&wb, lo, t)).unwrap();
        }
        assert_eq!(table.pool_used_blocks(), 4, "pool is full");
        let nw = table.open(4).unwrap();
        table.step(wreq(&wc, nw, 0)).unwrap();
        assert_eq!(
            table.is_preempted(lo),
            Some(true),
            "the Bulk session is the preferred victim"
        );
        assert_eq!(
            table.is_preempted(hi),
            Some(false),
            "the Interactive session stays resident"
        );
        let tb = table.close(lo).unwrap();
        assert_eq!(tb, decode_workload(DecodeKind::MemoryFree, &wb).unwrap());
    }

    #[test]
    fn prompt_validation_rejects_ragged_and_oversized_prompts() {
        let w = Workload::random(5, 4, 0xBAD5);
        let mut table = SessionTable::new(SessionConfig {
            kind: DecodeKind::MemoryFree,
            max_len: 4,
            ..SessionConfig::default()
        })
        .unwrap();
        let mut ragged = prompt_of(&w, 3);
        ragged.k.pop();
        let err = table.open_with_spec(4, None, Priority::Standard, Some(ragged));
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("ragged")),
            "ragged prompts must be rejected"
        );
        let mut short = prompt_of(&w, 2);
        short.q[1] = vec![0.0; 3];
        let err = table.open_with_spec(4, None, Priority::Standard, Some(short));
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("dim")),
            "wrong-width prompt rows must be rejected"
        );
        let err = table.open_with_spec(4, None, Priority::Standard, Some(prompt_of(&w, 5)));
        assert!(
            matches!(err, Err(Error::Coordinator(msg)) if msg.contains("context window")),
            "a 5-row unwindowed prompt exceeds max_len = 4"
        );
        // The same prompt fits a windowed session (the ring bounds
        // residency, not the prompt length).
        let id = table
            .open_with_spec(4, Some(2), Priority::Standard, Some(prompt_of(&w, 5)))
            .unwrap();
        assert_eq!(table.prefill_remaining(id), Some(5));
        // An empty prompt is the same as no prompt at all.
        let plain = table
            .open_with_spec(4, None, Priority::Standard, Some(PrefillPrompt::default()))
            .unwrap();
        assert_eq!(table.prefill_state(plain), None);
        assert_eq!(table.prefill_remaining(plain), Some(0));
    }
}
