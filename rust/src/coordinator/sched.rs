//! Token-budget, SLO-aware wave planning.
//!
//! The serving loop used to be flush-everything: every scheduling
//! iteration took one pending decode step from every active session and
//! ran the whole set as one wave, and a session's prompt could only
//! enter the cache one row per wave. This module is the planner that
//! replaces that — the TGI-router shape named in ROADMAP.md:
//!
//! * **`max_batch_total_tokens`** caps the keys streamed per wave (a
//!   decode step at cache length L costs L+1 keys; a prefill row t
//!   costs t+1, or just its granted span when the row splits). This is
//!   the wave's simulated-area budget: every key is one element through
//!   a lane's pipeline.
//! * **`max_batch_prefill_tokens`** caps prompt rows ingested per wave,
//!   bounding how much of a wave new prompts can claim.
//! * **`waiting_served_ratio`** trades new-request TTFT against
//!   running-session ITL: when waiting prefill sessions outnumber
//!   running decoders by the ratio, the prefill group plans first.
//! * **Priority / deadline classes** ([`Priority`]) order candidates
//!   within a group, and **starvation-free aging** guarantees no
//!   candidate waits more than its deadline bound: once a candidate's
//!   age reaches `min(aging_waves, priority.deadline_waves())` it is
//!   force-planned ahead of everything, budgets notwithstanding.
//!
//! [`plan_wave`] is pure — candidates in, plan out, no clocks and no
//! state — so every scheduling decision is deterministic and unit
//! testable, and the serving loop, the fleet replay, and the benches
//! all share one planner.

use std::cmp::Reverse;

/// Per-request service class: who goes first when a wave cannot take
/// everyone, and how long a request may age before it is force-planned.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-critical (chat turn): first in line, 2-wave deadline.
    Interactive,
    /// The default class: 8-wave deadline.
    #[default]
    Standard,
    /// Throughput work (batch scoring): last in line, 32-wave deadline.
    Bulk,
}

impl Priority {
    /// Every class, best-first.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Bulk];

    /// Sort rank, lower first.
    pub fn rank(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Bulk => 2,
        }
    }

    /// Stable lowercase name (reports, trace encoding, CLI).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Bulk => "bulk",
        }
    }

    /// Parse a class name (inverse of [`Self::name`]).
    pub fn parse(s: &str) -> Option<Priority> {
        match s.trim().to_ascii_lowercase().as_str() {
            "interactive" => Some(Priority::Interactive),
            "standard" => Some(Priority::Standard),
            "bulk" => Some(Priority::Bulk),
            _ => None,
        }
    }

    /// The class's deadline, in waves: how long a pending request may
    /// go unplanned before aging forces it into the next wave.
    pub fn deadline_waves(self) -> u64 {
        match self {
            Priority::Interactive => 2,
            Priority::Standard => 8,
            Priority::Bulk => 32,
        }
    }

    /// Class from `rank()` (array-indexed per-class stats).
    pub fn from_rank(rank: usize) -> Priority {
        Priority::ALL[rank]
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Budget knobs of the budgeted planner (the TGI router shape).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// Max prompt rows ingested per wave, across all sessions.
    pub max_batch_prefill_tokens: usize,
    /// Max keys streamed per wave, across decode steps and prefill
    /// segments (a step at cache length L costs L+1 keys).
    pub max_batch_total_tokens: usize,
    /// When `waiting ≥ ratio · running`, the prefill group plans ahead
    /// of the decode group (new-request TTFT over running-session ITL).
    pub waiting_served_ratio: f32,
    /// Max prompt rows one session ingests per wave (its chunk size).
    pub prefill_chunk: usize,
    /// Hard starvation bound: a candidate aged this many waves is
    /// force-planned regardless of budgets (per-class deadlines can
    /// only tighten this, never loosen it).
    pub aging_waves: u64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch_prefill_tokens: 8,
            max_batch_total_tokens: 64,
            waiting_served_ratio: 1.2,
            prefill_chunk: 4,
            aging_waves: 8,
        }
    }
}

/// Which scheduler the serving loop runs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum SchedPolicy {
    /// The pre-budget behavior: every candidate is planned every wave,
    /// prompts enter one whole row per wave. The baseline the perf
    /// regression guard measures against.
    #[default]
    Flush,
    /// Token-budget, SLO-aware planning with chunked prefill.
    Budgeted(SchedulerConfig),
}

impl SchedPolicy {
    /// Stable lowercase name (reports, bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            SchedPolicy::Flush => "flush",
            SchedPolicy::Budgeted(_) => "budgeted",
        }
    }
}

/// What a candidate wants from the next wave.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CandidateKind {
    /// One pending decode step; `keys_cost` = cache length + 1.
    Decode {
        /// Keys the step will stream.
        keys_cost: usize,
    },
    /// An in-flight prompt: rows `next_row..rows_total` remain, with
    /// `keys_done` keys of row `next_row` already scanned into the
    /// session's carry.
    Prefill {
        /// Total prompt rows.
        rows_total: usize,
        /// Rows fully ingested so far.
        next_row: usize,
        /// Keys of row `next_row` already scanned (0 = row not started).
        keys_done: usize,
        /// Whether rows may stop mid-scan (memory-free, unwindowed
        /// sessions). Non-splittable rows are granted whole or not at
        /// all.
        splittable: bool,
    },
}

/// One session's bid for the next wave.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WaveCandidate {
    /// Session id.
    pub session: u64,
    /// What the session wants to run.
    pub kind: CandidateKind,
    /// Service class.
    pub priority: Priority,
    /// Waves this candidate has gone without progress.
    pub age: u64,
}

impl WaveCandidate {
    fn is_prefill(&self) -> bool {
        matches!(self.kind, CandidateKind::Prefill { .. })
    }

    /// The wave count at which this candidate is force-planned.
    fn deadline(&self, cfg: &SchedulerConfig) -> u64 {
        cfg.aging_waves.min(self.priority.deadline_waves())
    }
}

/// What the planner granted one session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanAction {
    /// Run the session's pending decode step.
    Step,
    /// Advance the session's prefill by at most `max_rows` rows /
    /// `max_keys` keys (the table stages the actual segments).
    Prefill {
        /// Row grant (continuations count as one row).
        max_rows: usize,
        /// Key grant across the granted rows.
        max_keys: usize,
    },
}

/// One planned wave entry. The plan's order is the staging order, so
/// earlier entries claim pool blocks first under pressure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanItem {
    /// Session id.
    pub session: u64,
    /// Granted action.
    pub action: PlanAction,
}

/// Plan the next wave. Pure and deterministic: the same candidates and
/// policy always yield the same plan.
///
/// Guarantees:
/// * With any candidates at all, at least one is planned (budgets can
///   throttle, never stall).
/// * A candidate whose age reaches its deadline bound is planned this
///   wave, before every unforced candidate.
/// * Under [`SchedPolicy::Flush`], every candidate is planned, prompts
///   one whole row each — the pre-budget behavior.
pub fn plan_wave(policy: &SchedPolicy, candidates: &[WaveCandidate]) -> Vec<PlanItem> {
    let cfg = match policy {
        SchedPolicy::Flush => {
            return candidates
                .iter()
                .filter_map(|c| {
                    let action = match c.kind {
                        CandidateKind::Decode { .. } => PlanAction::Step,
                        CandidateKind::Prefill {
                            rows_total,
                            next_row,
                            ..
                        } => {
                            if next_row >= rows_total {
                                return None;
                            }
                            PlanAction::Prefill {
                                max_rows: 1,
                                max_keys: usize::MAX,
                            }
                        }
                    };
                    Some(PlanItem {
                        session: c.session,
                        action,
                    })
                })
                .collect();
        }
        SchedPolicy::Budgeted(cfg) => cfg,
    };

    // Forced first (deadline reached), oldest first; then the two
    // groups, prefill ahead of decode when the waiting/served ratio
    // says so, each group best-class-first, oldest-first within class.
    let waiting = candidates.iter().filter(|c| c.is_prefill()).count();
    let running = candidates.len() - waiting;
    let prefill_first =
        running == 0 || waiting as f32 >= cfg.waiting_served_ratio * running as f32;
    let mut order: Vec<usize> = (0..candidates.len()).collect();
    order.sort_by_key(|&i| {
        let c = &candidates[i];
        let forced = c.age >= c.deadline(cfg);
        let group = match (c.is_prefill(), prefill_first) {
            (true, true) | (false, false) => 0u8,
            _ => 1,
        };
        (!forced, group, c.priority.rank(), Reverse(c.age), c.session)
    });

    let mut total_left = cfg.max_batch_total_tokens;
    let mut prefill_left = cfg.max_batch_prefill_tokens;
    let mut plan = Vec::new();
    for i in order {
        let c = &candidates[i];
        // Forced candidates and the wave's first grant ignore budget
        // exhaustion: a wave always makes progress.
        let force = c.age >= c.deadline(cfg) || plan.is_empty();
        match c.kind {
            CandidateKind::Decode { keys_cost } => {
                if force || keys_cost <= total_left {
                    plan.push(PlanItem {
                        session: c.session,
                        action: PlanAction::Step,
                    });
                    total_left = total_left.saturating_sub(keys_cost);
                }
            }
            CandidateKind::Prefill {
                rows_total,
                next_row,
                keys_done,
                splittable,
            } => {
                let mut rows = 0usize;
                let mut keys = 0usize;
                let mut t = next_row;
                let mut kd = keys_done;
                while t < rows_total && rows < cfg.prefill_chunk {
                    let first = rows == 0;
                    if !first || !force {
                        if rows >= prefill_left {
                            break;
                        }
                    }
                    let rem = (t + 1) - kd;
                    let key_room = total_left.saturating_sub(keys);
                    if rem <= key_room {
                        rows += 1;
                        keys += rem;
                        t += 1;
                        kd = 0;
                    } else if splittable && key_room > 0 {
                        // Partial tail segment: take what the budget
                        // still holds and stop mid-row.
                        rows += 1;
                        keys += key_room;
                        break;
                    } else if first && force {
                        // Guaranteed progress: one whole row even when
                        // over budget (non-splittable rows cannot stop
                        // mid-scan).
                        rows += 1;
                        keys += rem;
                        break;
                    } else {
                        break;
                    }
                }
                if rows > 0 {
                    plan.push(PlanItem {
                        session: c.session,
                        action: PlanAction::Prefill {
                            max_rows: rows,
                            max_keys: keys,
                        },
                    });
                    prefill_left = prefill_left.saturating_sub(rows);
                    total_left = total_left.saturating_sub(keys);
                }
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode(session: u64, len: usize) -> WaveCandidate {
        WaveCandidate {
            session,
            kind: CandidateKind::Decode {
                keys_cost: len + 1,
            },
            priority: Priority::Standard,
            age: 0,
        }
    }

    fn prefill(session: u64, rows_total: usize) -> WaveCandidate {
        WaveCandidate {
            session,
            kind: CandidateKind::Prefill {
                rows_total,
                next_row: 0,
                keys_done: 0,
                splittable: true,
            },
            priority: Priority::Standard,
            age: 0,
        }
    }

    fn cfg() -> SchedulerConfig {
        SchedulerConfig::default()
    }

    #[test]
    fn flush_plans_every_candidate_one_row_prompts() {
        let cands = [decode(1, 5), prefill(2, 6), decode(3, 2)];
        let plan = plan_wave(&SchedPolicy::Flush, &cands);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan[0].action, PlanAction::Step);
        assert_eq!(
            plan[1].action,
            PlanAction::Prefill {
                max_rows: 1,
                max_keys: usize::MAX
            }
        );
        assert_eq!(plan[2].action, PlanAction::Step);
    }

    #[test]
    fn total_token_budget_throttles_decode() {
        // Three steps of 11 keys each under a 24-key budget: two fit.
        let cands = [decode(1, 10), decode(2, 10), decode(3, 10)];
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_total_tokens: 24,
            ..cfg()
        });
        let plan = plan_wave(&policy, &cands);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].session, 1);
        assert_eq!(plan[1].session, 2);
    }

    #[test]
    fn zero_budgets_still_plan_one_candidate() {
        let cands = [decode(7, 100), prefill(9, 50)];
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            ..cfg()
        });
        let plan = plan_wave(&policy, &cands);
        assert_eq!(plan.len(), 1, "a wave always makes progress");
    }

    #[test]
    fn waiting_served_ratio_boosts_prefill_ahead_of_decode() {
        // 2 waiting vs 1 running: ratio 1.2 → 2 ≥ 1.2·1 → prefill first.
        let cands = [decode(1, 3), prefill(2, 2), prefill(3, 2)];
        let policy = SchedPolicy::Budgeted(cfg());
        let plan = plan_wave(&policy, &cands);
        assert!(matches!(plan[0].action, PlanAction::Prefill { .. }));
        assert!(matches!(plan[1].action, PlanAction::Prefill { .. }));
        assert_eq!(plan[2].action, PlanAction::Step);

        // 1 waiting vs 2 running: 1 < 1.2·2 → decode first.
        let cands = [prefill(1, 2), decode(2, 3), decode(3, 3)];
        let plan = plan_wave(&policy, &cands);
        assert_eq!(plan[0].action, PlanAction::Step);
        assert_eq!(plan[1].action, PlanAction::Step);
        assert!(matches!(plan[2].action, PlanAction::Prefill { .. }));
    }

    #[test]
    fn priorities_order_within_a_group() {
        let mut a = decode(1, 3);
        a.priority = Priority::Bulk;
        let mut b = decode(2, 3);
        b.priority = Priority::Interactive;
        let c = decode(3, 3);
        let plan = plan_wave(&SchedPolicy::Budgeted(cfg()), &[a, b, c]);
        assert_eq!(
            plan.iter().map(|p| p.session).collect::<Vec<_>>(),
            vec![2, 3, 1],
            "interactive, standard, bulk"
        );
    }

    #[test]
    fn aged_candidate_is_forced_ahead_despite_budget_and_class() {
        let mut starved = decode(9, 50);
        starved.priority = Priority::Bulk;
        starved.age = 32; // at the bulk deadline
        let fresh = decode(1, 3);
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_total_tokens: 4,
            ..cfg()
        });
        let plan = plan_wave(&policy, &[fresh, starved]);
        assert_eq!(plan[0].session, 9, "deadline-aged bulk step jumps the queue");
    }

    #[test]
    fn interactive_deadline_is_tighter_than_aging_waves() {
        let mut urgent = prefill(5, 4);
        urgent.priority = Priority::Interactive;
        urgent.age = 2; // interactive deadline, well under aging_waves=8
        let fresh = decode(1, 2);
        let plan = plan_wave(&SchedPolicy::Budgeted(cfg()), &[fresh, urgent]);
        assert_eq!(plan[0].session, 5);
    }

    #[test]
    fn prefill_grant_respects_chunk_and_splits_the_tail_row() {
        // A fresh 10-row prompt under chunk 4 and a 6-key total budget:
        // rows 0 (1 key), 1 (2), 2 (3 → only 3 left) — row 2 fits
        // exactly; grant is 3 rows / 6 keys.
        let cand = prefill(4, 10);
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_total_tokens: 6,
            ..cfg()
        });
        let plan = plan_wave(&policy, &[cand]);
        assert_eq!(
            plan[0].action,
            PlanAction::Prefill {
                max_rows: 3,
                max_keys: 6
            }
        );

        // A 5-key budget splits row 2 after 2 of its 3 keys.
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_total_tokens: 5,
            ..cfg()
        });
        let plan = plan_wave(&policy, &[cand]);
        assert_eq!(
            plan[0].action,
            PlanAction::Prefill {
                max_rows: 3,
                max_keys: 5
            }
        );

        // Non-splittable rows are granted whole or not at all.
        let mut ns = cand;
        ns.kind = CandidateKind::Prefill {
            rows_total: 10,
            next_row: 0,
            keys_done: 0,
            splittable: false,
        };
        let plan = plan_wave(&policy, &[ns]);
        assert_eq!(
            plan[0].action,
            PlanAction::Prefill {
                max_rows: 2,
                max_keys: 3
            },
            "rows 0+1 fit whole; row 2 would split, so it waits"
        );
    }

    #[test]
    fn mid_row_continuation_costs_only_the_remaining_keys() {
        // Row 7 of 8 with 5 of its 8 keys done: continuation costs 3.
        let cand = WaveCandidate {
            session: 2,
            kind: CandidateKind::Prefill {
                rows_total: 8,
                next_row: 7,
                keys_done: 5,
                splittable: true,
            },
            priority: Priority::Standard,
            age: 0,
        };
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_total_tokens: 3,
            ..cfg()
        });
        let plan = plan_wave(&policy, &[cand]);
        assert_eq!(
            plan[0].action,
            PlanAction::Prefill {
                max_rows: 1,
                max_keys: 3
            }
        );
    }

    #[test]
    fn prefill_token_budget_caps_rows_across_sessions() {
        let cands = [prefill(1, 4), prefill(2, 4), prefill(3, 4)];
        let policy = SchedPolicy::Budgeted(SchedulerConfig {
            max_batch_prefill_tokens: 5,
            max_batch_total_tokens: 1000,
            ..cfg()
        });
        let plan = plan_wave(&policy, &cands);
        let rows: usize = plan
            .iter()
            .map(|p| match p.action {
                PlanAction::Prefill { max_rows, .. } => max_rows,
                PlanAction::Step => 0,
            })
            .sum();
        assert_eq!(rows, 5, "4 + 1 rows under the 5-row prefill budget");
    }

    #[test]
    fn plans_are_deterministic_and_name_stable() {
        let cands = [decode(3, 4), prefill(1, 6), decode(2, 9)];
        let policy = SchedPolicy::Budgeted(cfg());
        assert_eq!(plan_wave(&policy, &cands), plan_wave(&policy, &cands));
        assert_eq!(SchedPolicy::Flush.name(), "flush");
        assert_eq!(policy.name(), "budgeted");
        assert_eq!(Priority::parse("BULK"), Some(Priority::Bulk));
        assert_eq!(Priority::parse("nope"), None);
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()), Some(p));
            assert_eq!(Priority::from_rank(p.rank() as usize), p);
        }
        assert!(Priority::Interactive.deadline_waves() < Priority::Bulk.deadline_waves());
    }
}
