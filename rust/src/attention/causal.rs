//! Causal (masked) prefill on the streaming engine, and the
//! causal-aware long-FIFO bound.
//!
//! ## In-stream masking
//!
//! The four prefill graphs stream N² scores row-major. Masking is a
//! *configured address pattern*, not data: a stateless mask source is
//! zipped into the score front-end
//! ([`score_frontend_masked`](super::score_frontend_masked)) and masked
//! positions emit −∞. Downstream everything follows from IEEE
//! arithmetic: `exp(−∞) = 0` drops the position from every row sum and
//! contraction, `max(m, −∞) = m` leaves the row max alone, and the
//! memory-free running scans reduce to exact identity updates
//! (`Δ = 1`, `e = 0`). The prefix masks (causal, ragged) keep key 0
//! visible to every row, so the running max is seeded before any
//! masked position arrives; [`Mask::Window`] masks the *front* of a
//! row, so the memory-free scan carries an explicit unseeded guard
//! (`Δ = e = 0` while the running max is still −∞ — see
//! [`super::memfree`]) and the buffering variants are safe as-is
//! (their row max is taken over the whole row, and the diagonal is
//! always visible).
//!
//! ## The causal depth bound
//!
//! In-stream masking does **not** change any FIFO bound: masked
//! elements still occupy one stream slot per cycle, so the
//! Broadcast→Reduce→Zip imbalance the compile stage measures — and the
//! N+2 bypass depth it derives — is identical to the unmasked graph.
//! (`causal_inference_matches_unmasked_bound` asserts this.)
//!
//! The causal *savings* appear only under a **compressed** mapping that
//! streams just the visible span: a row with ℓ visible keys then has
//! a Reduce window of ℓ, and the reconvergence analysis yields a bypass
//! depth of ℓ+2 ([`long_fifo_bound`]) instead of N+2. The decode-step
//! graphs of [`super::decode`] are exactly this mapping (one row, ℓ =
//! cache length — or `min(len, W)` for a windowed session, which is
//! how a sliding window also compresses the decode-step FIFO bound)
//! and the compile stage re-derives the bound per step — asserted in
//! `decode`'s tests. The memory-free recurrence needs no bypass either
//! way: its bound is 2, independent of ℓ and N, which is why causal
//! decode inherits the paper's O(1)-memory headline intact.

use super::workload::{Mask, Workload};
use super::{flashd, memfree, naive, reordered, scaled, BuiltAttention, DepthPolicy, Variant};
use crate::{Error, Result};

/// Build a masked prefill graph for a base prefill variant — one of
/// the paper's four ([`Variant::PAPER`]) or the division-free
/// [`Variant::FlashD`] extension. Causal/decode members are themselves
/// built on top of this dispatch and are rejected here.
pub fn build_masked(
    base: Variant,
    w: &Workload,
    mask: &Mask,
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    match base {
        Variant::Naive => naive::build_masked_with_policy(w, mask, policy),
        Variant::Scaled => scaled::build_masked_with_policy(w, mask, policy),
        Variant::Reordered => reordered::build_masked_with_policy(w, mask, policy),
        Variant::MemoryFree => memfree::build_masked_with_policy(w, mask, policy),
        Variant::FlashD => flashd::build_masked_with_policy(w, mask, policy),
        other => Err(Error::Graph(format!(
            "build_masked takes a base prefill variant (one of \
             naive|scaled|reordered|memfree|flashd), got '{other}'"
        ))),
    }
}

/// Build the causal prefill graph for a base variant.
pub fn build_causal(base: Variant, w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_masked(base, w, &Mask::Causal, policy)
}

/// Long-FIFO depth a *compressed* causal row with `visible` keys needs
/// under each base algorithm: the buffering variants pay
/// `visible + 2` (the N+2 bound with the row's own length), the
/// memory-free recurrence a constant 2. The decode-step graphs
/// instantiate this bound and the compile-time inference re-derives it
/// — see [`super::decode::step_long_fifo_bound`].
pub fn long_fifo_bound(base: Variant, visible: usize) -> usize {
    assert!(visible >= 1, "a row attends at least one key");
    match base.base() {
        Variant::MemoryFree | Variant::FlashD => 2,
        _ => visible + 2,
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference::{
        assert_close, sdpa_f32_scaled_masked, sdpa_f64_masked, sdpa_online_f32_masked,
    };
    use super::*;
    use crate::sim::{Capacity, RunOutcome};

    #[test]
    fn every_base_variant_matches_the_masked_references() {
        let w = Workload::random(12, 6, 0xCA05);
        for mask in [Mask::Causal, Mask::ragged(5), Mask::window(4)] {
            let gold = sdpa_f64_masked(&w, &mask);
            for base in Variant::PAPER {
                let mut built = build_masked(base, &w, &mask, DepthPolicy::Inferred).unwrap();
                let (got, summary) = built.run().unwrap();
                assert_eq!(summary.outcome, RunOutcome::Completed);
                assert_close(
                    &got,
                    &gold,
                    1e-4,
                    &format!("{base} masked {} vs f64", mask.name()),
                );
            }
            // Structure-matched f32 agreement is much tighter.
            let mut scaled =
                build_masked(Variant::Scaled, &w, &mask, DepthPolicy::Inferred).unwrap();
            let (got, _) = scaled.run().unwrap();
            assert_close(
                &got,
                &sdpa_f32_scaled_masked(&w, &mask),
                1e-6,
                "scaled masked f32 structure match",
            );
            let mut mf =
                build_masked(Variant::MemoryFree, &w, &mask, DepthPolicy::Inferred).unwrap();
            let (got, _) = mf.run().unwrap();
            assert_close(
                &got,
                &sdpa_online_f32_masked(&w, &mask),
                1e-6,
                "memfree masked f32 structure match",
            );
        }
    }

    #[test]
    fn causal_inference_matches_unmasked_bound() {
        // The documented claim: in-stream masking leaves every long-FIFO
        // bound untouched — masked slots still occupy stream slots.
        let w = Workload::random(16, 4, 0xCA06);
        for mask in [Mask::Causal, Mask::window(4)] {
            for base in [Variant::Naive, Variant::Scaled, Variant::Reordered] {
                let built = build_masked(base, &w, &mask, DepthPolicy::Inferred).unwrap();
                for name in base.long_fifos() {
                    let rec = built
                        .engine
                        .depth_report()
                        .iter()
                        .find(|c| c.name == *name)
                        .unwrap();
                    assert!(rec.is_long, "{base} {}: {name}", mask.name());
                    assert_eq!(rec.inferred, w.n + 2, "{base} {}: {name}", mask.name());
                }
            }
            // The masked memory-free graph stays all-short.
            let built = build_masked(Variant::MemoryFree, &w, &mask, DepthPolicy::Inferred).unwrap();
            for c in built.engine.depth_report() {
                assert!(!c.is_long, "{}: channel '{}'", mask.name(), c.name);
                assert_eq!(
                    c.capacity,
                    Capacity::Bounded(2),
                    "{}: channel '{}'",
                    mask.name(),
                    c.name
                );
            }
        }
    }

    #[test]
    fn compressed_bound_is_len_plus_2_for_buffering_variants() {
        for len in [1usize, 4, 16] {
            assert_eq!(long_fifo_bound(Variant::Naive, len), len + 2);
            assert_eq!(long_fifo_bound(Variant::CausalScaled, len), len + 2);
            assert_eq!(long_fifo_bound(Variant::MemoryFree, len), 2);
            assert_eq!(long_fifo_bound(Variant::Decode, len), 2);
            assert_eq!(long_fifo_bound(Variant::FlashD, len), 2);
        }
    }

    #[test]
    fn non_base_variants_are_rejected() {
        let w = Workload::random(4, 4, 1);
        let err = build_masked(
            Variant::CausalNaive,
            &w,
            &Mask::Causal,
            DepthPolicy::Inferred,
        );
        assert!(matches!(err, Err(Error::Graph(msg)) if msg.contains("base prefill")));
    }
}
