//! Autoregressive decode on the streaming engine.
//!
//! Token-by-token serving is the compressed causal mapping of
//! [`super::causal`]: at step `t` the new query row `q_t` streams
//! against the `t+1` cached K/V rows — only the visible prefix, no
//! masked bubbles. Two step mappings are provided:
//!
//! * [`DecodeKind::MemoryFree`] — the paper's reordered online-softmax
//!   recurrence. The `(m, ℓ⃗, r)` state rides element-wise `Scan`s along
//!   the K/V stream, so every FIFO is depth 2 and intermediate memory
//!   is **O(1) per step, independent of the cache length** — the
//!   paper's headline carried into decode.
//! * [`DecodeKind::Buffered`] — the Figure-2 mapping of the same step:
//!   exponentials buffer in an `e_bypass` FIFO while the row sum
//!   reduces, which needs depth `len + 2`
//!   ([`step_long_fifo_bound`], the causal-aware bound the compile
//!   stage re-derives per step). Kept as the O(len) contrast the
//!   scaling study measures.
//!
//! [`DecodeSession`] chains steps: it owns the growing K/V cache and
//! replays it into a fresh step graph per token (the simulator's
//! equivalent of re-configuring the fabric's address generators for the
//! new sequence length). Graph state never leaks across steps — the
//! per-query softmax state is carried *within* a step by the scans, and
//! the only cross-step state is the K/V cache itself. A full session
//! over a workload ([`decode_workload`]) must therefore agree with the
//! causal prefill references row for row; `tests/causal_decode.rs`
//! enforces this differentially, along with bit-identical
//! `Engine::reset` replays of step graphs.

use super::reference::Matrix;
use super::workload::{dot, Workload};
use super::{BuiltAttention, DepthPolicy};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Elem, GraphBuilder, RunSummary, SchedulerMode, Scope};
use crate::{Error, Result};

/// Which decode-step mapping to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeKind {
    /// Figure-2 style: buffer exponentials while the row sum reduces —
    /// `e_bypass` needs depth len+2, O(len) memory per step.
    Buffered,
    /// Figure-3(c) style: running max/sum scans — every FIFO depth 2,
    /// O(1) memory per step.
    MemoryFree,
}

impl DecodeKind {
    /// Both mappings, buffered (contrast) first.
    pub const ALL: [DecodeKind; 2] = [DecodeKind::Buffered, DecodeKind::MemoryFree];

    /// Stable lowercase name (reports, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            DecodeKind::Buffered => "buffered",
            DecodeKind::MemoryFree => "memfree",
        }
    }
}

impl std::fmt::Display for DecodeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Long-FIFO depth one decode step needs at cache length `len` — the
/// causal-aware bound ([`super::causal::long_fifo_bound`] with
/// `visible = len`). `DepthPolicy::Inferred` re-derives exactly this
/// from the step graph's structure.
pub fn step_long_fifo_bound(kind: DecodeKind, len: usize) -> usize {
    match kind {
        DecodeKind::Buffered => len + 2,
        DecodeKind::MemoryFree => 2,
    }
}

/// Build one decode step: query row `q` against `len = keys.len()`
/// cached K/V rows. The returned graph emits exactly one output row.
pub fn build_step(
    kind: DecodeKind,
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let len = keys.len();
    let d = q.len();
    let mut g = GraphBuilder::new();
    let mut sc = g.root();
    let out = build_step_into(&mut sc, kind, q, keys, values)?;
    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n: len,
        d,
    })
}

/// The decode-step pipeline, buildable into any scope — the composition
/// point for the multi-lane serving engines: one scheduling iteration of
/// the continuous-batching server instantiates one of these per active
/// session inside its own lane scope (see
/// [`super::multihead::build_decode_lanes`]), exactly the way attention
/// heads compose spatially. Inputs are validated the same way
/// [`build_step`] validates them.
pub fn build_step_into(
    sc: &mut Scope<'_>,
    kind: DecodeKind,
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
) -> Result<SinkHandle> {
    let len = keys.len();
    let d = q.len();
    if len == 0 {
        return Err(Error::Graph(
            "decode step needs at least one cached K/V row".into(),
        ));
    }
    if d == 0 {
        return Err(Error::Graph("decode step: query row is empty".into()));
    }
    if values.len() != len {
        return Err(Error::Graph(format!(
            "decode step: {} keys but {} values",
            len,
            values.len()
        )));
    }
    if let Some(row) = keys.iter().chain(values).find(|r| r.len() != d) {
        return Err(Error::Graph(format!(
            "decode step: cached row has dim {}, query has {}",
            row.len(),
            d
        )));
    }
    let scale = 1.0 / (d as f32).sqrt();

    // One query row, replayed once per cached key; K/V replay from the
    // cache (resident operands — stateless, reset-safe sources).
    let q_rows = sc.source_vec("src_q", vec![Elem::vector(q)])?;
    let q_rep = sc.repeat("rep_q", q_rows, len)?;
    let k: Vec<Elem> = keys.iter().map(|r| Elem::vector(r)).collect();
    let k_cols = sc.source_gen("src_k", len as u64, move |j| k[j as usize].clone())?;
    let s = sc.zip("qk_dot", [q_rep, k_cols], move |xs| {
        Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
    })?;
    let v: Vec<Elem> = values.iter().map(|r| Elem::vector(r)).collect();

    match kind {
        DecodeKind::MemoryFree => {
            // Eq. 4: running max → (Δ, e) per cached key.
            let neg_inf = Elem::Pair(f32::NEG_INFINITY, f32::NEG_INFINITY);
            let de = sc.scan(
                "run_max",
                s,
                len,
                neg_inf,
                |st, x| {
                    let (_, m_old) = st.pair();
                    let m_new = m_old.max(x.scalar());
                    Elem::Pair(m_old, m_new)
                },
                |st, x| {
                    let (m_old, m_new) = st.pair();
                    let delta = (m_old - m_new).exp();
                    let e = (x.scalar() - m_new).exp();
                    Elem::Pair(delta, e)
                },
            )?;
            let [de_r, de_l] = sc.broadcast("bc_de", de, ["de_r", "de_l"])?;

            // Eq. 5 scalar: r ← r·Δ + e.
            let r_run = sc.scan(
                "run_sum",
                de_r,
                len,
                Elem::Scalar(0.0),
                |st, x| {
                    let (delta, e) = x.pair();
                    Elem::Scalar(st.scalar() * delta + e)
                },
                |st, _| st.clone(),
            )?;
            let r = sc.last_of("last_r", r_run, len)?;

            // Eq. 5 vector: l⃗ ← l⃗·Δ + e·v⃗_j.
            let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
            let dev = sc.zip("zip_v", [de_l, v_cols], |xs| {
                Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
            })?;
            let l_run = sc.scan(
                "run_out",
                dev,
                len,
                Elem::from(vec![0.0f32; d]),
                |st, x| {
                    let (delta, e) = x.as_tuple()[0].pair();
                    let vv = x.as_tuple()[1].as_vector();
                    Elem::from(
                        st.as_vector()
                            .iter()
                            .zip(vv)
                            .map(|(acc, v)| acc * delta + e * v)
                            .collect::<Vec<_>>(),
                    )
                },
                |st, _| st.clone(),
            )?;
            let l = sc.last_of("last_l", l_run, len)?;

            // Eq. 6: o⃗_t = l⃗ / r.
            let o = sc.zip("div", [l, r], |xs| {
                let r = xs[1].scalar();
                Elem::from(xs[0].as_vector().iter().map(|x| x / r).collect::<Vec<_>>())
            })?;
            sc.sink("sink_o", o, Some(1))
        }
        DecodeKind::Buffered => {
            // Figure-2 shape at window `len`: the bypass must hold the
            // whole visible prefix while the row sum reduces.
            let e = sc.map("exp", s, |x| Elem::Scalar(x.scalar().exp()))?;
            let [e_sum, e_bypass] = sc.broadcast("bc_e", e, ["e_sum", "e_bypass"])?;
            let sigma = sc.reduce("row_sum", e_sum, len, 0.0, |a, b| a + b)?;
            let sigma_rep = sc.repeat("rep_sigma", sigma, len)?;
            let p = sc.zip("div", [e_bypass, sigma_rep], |xs| {
                Elem::Scalar(xs[0].scalar() / xs[1].scalar())
            })?;
            let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
            let pv = sc.zip("pv_mul", [p, v_cols], |xs| {
                let p = xs[0].scalar();
                Elem::from(xs[1].as_vector().iter().map(|v| p * v).collect::<Vec<_>>())
            })?;
            let o = sc.mem_reduce("pv_acc", pv, len, vec![0.0; d], |acc, x| {
                acc.iter().zip(x.as_vector()).map(|(a, b)| a + b).collect()
            })?;
            sc.sink("sink_o", o, Some(1))
        }
    }
}

/// The serving steady state as a one-shot graph: the *last* decode step
/// of workload `w` (query row N−1 against the full K/V cache, the
/// memory-free mapping). This is what [`super::Variant::Decode`]
/// builds, so the whole experiment/test grid exercises decode through
/// the ordinary variant machinery.
pub fn build_last_row(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_step(DecodeKind::MemoryFree, &w.q[w.n - 1], &w.k, &w.v, policy)
}

/// One completed decode step.
#[derive(Clone, Debug)]
pub struct DecodeStepOutcome {
    /// 0-based step index within the session.
    pub step: usize,
    /// The attention output row o⃗_t.
    pub row: Vec<f32>,
    /// The step graph's run summary (cycles, occupancy, depth report).
    pub summary: RunSummary,
}

/// An autoregressive decode session: owns the growing K/V cache, builds
/// and runs one step graph per token, and accumulates the output rows.
pub struct DecodeSession {
    kind: DecodeKind,
    d: usize,
    policy: DepthPolicy,
    mode: Option<SchedulerMode>,
    keys: Vec<Vec<f32>>,
    values: Vec<Vec<f32>>,
    outputs: Matrix,
}

impl DecodeSession {
    /// New session for head dimension `d` with inferred FIFO depths.
    pub fn new(kind: DecodeKind, d: usize) -> Self {
        Self::with_policy(kind, d, DepthPolicy::Inferred)
    }

    /// New session under an explicit depth policy.
    pub fn with_policy(kind: DecodeKind, d: usize, policy: DepthPolicy) -> Self {
        assert!(d >= 1, "head dimension must be at least 1");
        DecodeSession {
            kind,
            d,
            policy,
            mode: None,
            keys: Vec::new(),
            values: Vec::new(),
            outputs: Vec::new(),
        }
    }

    /// Force a scheduler mode on every step engine (differential tests;
    /// the default is the engine's own default, i.e. `SDPA_SCHED`).
    pub fn set_scheduler_mode(&mut self, mode: SchedulerMode) {
        self.mode = Some(mode);
    }

    /// The step mapping this session uses.
    pub fn kind(&self) -> DecodeKind {
        self.kind
    }

    /// Tokens decoded so far (== cached K/V rows == output rows).
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Whether no token has been decoded yet.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Output rows accumulated so far, one per step.
    pub fn outputs(&self) -> &Matrix {
        &self.outputs
    }

    /// The cached key rows (one per decoded token).
    pub fn keys(&self) -> &[Vec<f32>] {
        &self.keys
    }

    /// The cached value rows (one per decoded token).
    pub fn values(&self) -> &[Vec<f32>] {
        &self.values
    }

    /// Validate one step's row shapes and append `(k, v)` to the cache —
    /// the first half of a step. The caller either runs the step graph
    /// and [`Self::commit_row`]s the result, or [`Self::unstage`]s on
    /// failure so the cache is left exactly as it was. The serving lane
    /// pool uses this split to run many sessions' staged steps in one
    /// engine (see `coordinator::sessions::SessionTable::step_wave`).
    pub(crate) fn stage(&mut self, q: &[f32], k: Vec<f32>, v: Vec<f32>) -> Result<()> {
        for (what, len) in [("q", q.len()), ("k", k.len()), ("v", v.len())] {
            if len != self.d {
                return Err(Error::Graph(format!(
                    "decode step {}: {what} has dim {}, session expects {}",
                    self.keys.len(),
                    len,
                    self.d
                )));
            }
        }
        self.keys.push(k);
        self.values.push(v);
        Ok(())
    }

    /// Undo the most recent [`Self::stage`] (a failed step must not
    /// corrupt the session: a retry sees the pre-step state).
    pub(crate) fn unstage(&mut self) {
        self.keys.pop();
        self.values.pop();
    }

    /// Record the staged step's output row, completing the step.
    pub(crate) fn commit_row(&mut self, row: Vec<f32>) {
        self.outputs.push(row);
    }

    /// Decode one token: append `(k, v)` to the cache, stream `q`
    /// against it, return the output row and the step's run summary.
    pub fn step(&mut self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Result<DecodeStepOutcome> {
        self.stage(&q, k, v)?;
        let result = build_step(self.kind, &q, &self.keys, &self.values, self.policy)
            .and_then(|mut built| {
                if let Some(mode) = self.mode {
                    built.engine.set_scheduler_mode(mode);
                }
                built.run()
            });
        let (rows, summary) = match result {
            Ok(ok) => ok,
            Err(e) => {
                // A failed step (e.g. deadlock under an undersized
                // explicit plan) must not corrupt the session: unwind
                // the cache so a retry sees the pre-step state.
                self.unstage();
                return Err(e);
            }
        };
        let row = rows.into_iter().next().expect("decode step emits one row");
        self.commit_row(row.clone());
        Ok(DecodeStepOutcome {
            step: self.keys.len() - 1,
            row,
            summary,
        })
    }
}

/// Run a full autoregressive pass over `w` — step `t` feeds
/// `(q_t, k_t, v_t)` — and return the N output rows. Must agree with
/// the causal prefill references row for row (the decode-chain half of
/// the differential conformance suite).
pub fn decode_workload(kind: DecodeKind, w: &Workload) -> Result<Matrix> {
    let mut session = DecodeSession::new(kind, w.d);
    for t in 0..w.n {
        session.step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())?;
    }
    Ok(session.outputs)
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f64_masked, sdpa_online_f32_masked};
    use super::super::workload::Mask;
    use super::super::{FifoPlan, Variant};
    use super::*;
    use crate::sim::Capacity;

    #[test]
    fn memfree_chain_matches_online_causal_reference_tightly() {
        let w = Workload::random(12, 8, 0xDEC1);
        let chain = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        // Same f32 operations in the same order as the oracle.
        assert_close(
            &chain,
            &sdpa_online_f32_masked(&w, &Mask::Causal),
            1e-6,
            "decode chain vs online causal",
        );
        assert_close(
            &chain,
            &sdpa_f64_masked(&w, &Mask::Causal),
            1e-4,
            "decode chain vs f64 causal",
        );
    }

    #[test]
    fn buffered_chain_matches_f64_causal() {
        let w = Workload::random(10, 4, 0xDEC2);
        let chain = decode_workload(DecodeKind::Buffered, &w).unwrap();
        assert_close(
            &chain,
            &sdpa_f64_masked(&w, &Mask::Causal),
            1e-4,
            "buffered decode chain vs f64 causal",
        );
    }

    #[test]
    fn inferred_step_depths_match_the_causal_bound() {
        let w = Workload::random(16, 4, 0xDEC3);
        for len in [1usize, 4, 16] {
            let p = w.prefix(len);
            let buffered = build_step(
                DecodeKind::Buffered,
                &p.q[len - 1],
                &p.k,
                &p.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            let long_max = buffered
                .engine
                .depth_report()
                .iter()
                .filter(|c| c.is_long)
                .map(|c| c.inferred)
                .max();
            assert_eq!(
                long_max,
                Some(step_long_fifo_bound(DecodeKind::Buffered, len)),
                "buffered len={len}"
            );

            let memfree = build_step(
                DecodeKind::MemoryFree,
                &p.q[len - 1],
                &p.k,
                &p.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            for c in memfree.engine.depth_report() {
                assert!(!c.is_long, "memfree len={len}: '{}'", c.name);
                assert_eq!(c.capacity, Capacity::Bounded(2), "len={len}: '{}'", c.name);
            }
        }
    }

    #[test]
    fn memfree_step_memory_is_constant_in_cache_length() {
        for len in [4usize, 16, 64] {
            let w = Workload::random(len, 4, 0xDEC4);
            let mut built = build_step(
                DecodeKind::MemoryFree,
                &w.q[len - 1],
                &w.k,
                &w.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            let (_, summary) = built.run().unwrap();
            for (name, st) in &summary.channel_stats {
                assert!(
                    st.peak_occupancy_elems <= 2,
                    "len={len}: channel '{name}' peaked at {}",
                    st.peak_occupancy_elems
                );
            }
        }
    }

    #[test]
    fn variant_decode_builds_the_last_chain_row() {
        let w = Workload::random(9, 4, 0xDEC5);
        let mut built = Variant::Decode
            .build(&w, &FifoPlan::paper(w.n))
            .unwrap();
        let (got, _) = built.run().unwrap();
        assert_eq!(got.len(), 1);
        let chain = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        let last: Matrix = vec![chain[w.n - 1].clone()];
        assert_close(&got, &last, 1e-6, "Variant::Decode vs chain last row");
    }

    #[test]
    fn session_validates_shapes_and_counts_steps() {
        let mut s = DecodeSession::new(DecodeKind::MemoryFree, 4);
        assert!(s.is_empty());
        let out = s
            .step(vec![0.1; 4], vec![0.2; 4], vec![0.3; 4])
            .unwrap();
        assert_eq!(out.step, 0);
        assert_eq!(out.row.len(), 4);
        let out = s
            .step(vec![0.4; 4], vec![0.5; 4], vec![0.6; 4])
            .unwrap();
        assert_eq!(out.step, 1);
        assert_eq!(s.len(), 2);
        assert_eq!(s.outputs().len(), 2);
        let err = s.step(vec![0.0; 3], vec![0.0; 4], vec![0.0; 4]);
        assert!(matches!(err, Err(Error::Graph(msg)) if msg.contains("dim 3")));
        // The failed step must not have touched the cache.
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn failed_step_leaves_the_session_cache_untouched() {
        // Under a depth-2 explicit plan the buffered step deadlocks as
        // soon as the cache outgrows the bypass (len = 3 > 2): the
        // broadcast can no longer land the last exponential before the
        // row sum completes. The error must not advance the cache — a
        // retry after the failure sees the pre-step state, not a
        // double-cached token.
        let mut s = DecodeSession::with_policy(
            DecodeKind::Buffered,
            4,
            DepthPolicy::Explicit(FifoPlan::with_long_depth(2)),
        );
        s.step(vec![0.1; 4], vec![0.2; 4], vec![0.3; 4]).unwrap();
        s.step(vec![0.4; 4], vec![0.5; 4], vec![0.6; 4]).unwrap();
        assert_eq!(s.len(), 2);
        let err = s.step(vec![0.7; 4], vec![0.8; 4], vec![0.9; 4]);
        assert!(err.is_err(), "undersized bypass must deadlock at len 3");
        assert_eq!(s.len(), 2, "failed step must not grow the cache");
        assert_eq!(s.outputs().len(), 2, "no phantom output row");
    }

    #[test]
    fn build_step_rejects_empty_and_ragged_caches() {
        let empty = build_step(DecodeKind::MemoryFree, &[1.0], &[], &[], DepthPolicy::Inferred);
        assert!(empty.is_err());
        let err = build_step(
            DecodeKind::MemoryFree,
            &[1.0, 2.0],
            &[vec![1.0, 2.0]],
            &[vec![1.0]],
            DepthPolicy::Inferred,
        );
        assert!(err.is_err());
    }
}
