//! Autoregressive decode on the streaming engine.
//!
//! Token-by-token serving is the compressed causal mapping of
//! [`super::causal`]: at step `t` the new query row `q_t` streams
//! against the `t+1` cached K/V rows — only the visible prefix, no
//! masked bubbles. Three step mappings are provided:
//!
//! * [`DecodeKind::MemoryFree`] — the paper's reordered online-softmax
//!   recurrence. The `(m, ℓ⃗, r)` state rides element-wise `Scan`s along
//!   the K/V stream, so every FIFO is depth 2 and intermediate memory
//!   is **O(1) per step, independent of the cache length** — the
//!   paper's headline carried into decode.
//! * [`DecodeKind::Buffered`] — the Figure-2 mapping of the same step:
//!   exponentials buffer in an `e_bypass` FIFO while the row sum
//!   reduces, which needs depth `len + 2`
//!   ([`step_long_fifo_bound`], the causal-aware bound the compile
//!   stage re-derives per step). Kept as the O(len) contrast the
//!   scaling study measures.
//! * [`DecodeKind::FlashD`] — the FLASH-D hidden-division mapping (see
//!   [`super::flashd`]): a running log-sum-exp scan emits
//!   already-normalized weights and the output rides an exact EMA, so
//!   the step has **no divider node at all**, every FIFO is depth 2,
//!   and memory stays O(1) per step.
//!
//! [`DecodeSession`] chains steps: it owns the growing K/V cache and
//! replays it into a fresh step graph per token (the simulator's
//! equivalent of re-configuring the fabric's address generators for the
//! new sequence length). Graph state never leaks across steps — the
//! per-query softmax state is carried *within* a step by the scans, and
//! the only cross-step state is the K/V cache itself. A full session
//! over a workload ([`decode_workload`]) must therefore agree with the
//! causal prefill references row for row; `tests/causal_decode.rs`
//! enforces this differentially, along with bit-identical
//! `Engine::reset` replays of step graphs.
//!
//! [`PagedDecodeSession`] is the serving twin: instead of contiguous
//! rows it holds a [`BlockTable`] into a shared, bounded [`BlockPool`]
//! (see [`crate::runtime::kvcache`]), which buys prefix sharing
//! ([`PagedDecodeSession::fork`]), copy-on-write tails, and swap-out
//! preemption. Each step gathers the table ([`BlockPool::view`]) and
//! replays exactly the same row stream through
//! [`build_step_rows_into`], so paged transcripts are **bit-identical**
//! to contiguous ones — `tests/paged_conformance.rs` enforces this
//! differentially, including across fork and preempt/requeue cycles.
//!
//! **Sliding-window decode** ([`DecodeSession::new_windowed`],
//! [`PagedDecodeSession::new_windowed`]) caps what a step attends: at
//! logical length `len` the step streams only the last `min(len, W)`
//! cached rows — the compressed mapping of `Mask::Window`, with no
//! in-graph masking and the step's FIFO bound shrunk to
//! `min(len, W) + 2` (buffered) / 2 (memory-free). The paged variant
//! additionally caps the *footprint*: its block table is a ring that
//! evicts rows older than the window in place (see
//! [`crate::runtime::kvcache`]), so a windowed session holds at most
//! ⌈W/block_size⌉ blocks however long it runs. Both variants and a
//! per-step truncated oracle are proven bitwise-identical in
//! `tests/windowed_conformance.rs`.

use super::reference::Matrix;
use super::workload::{dot, Workload};
use super::{BuiltAttention, DepthPolicy};
use crate::runtime::kvcache::{AppendUndo, BlockPool, BlockTable, SwappedKv};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Elem, GraphBuilder, RunSummary, SchedulerMode, Scope};
use crate::{Error, Result};

/// Which decode-step mapping to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeKind {
    /// Figure-2 style: buffer exponentials while the row sum reduces —
    /// `e_bypass` needs depth len+2, O(len) memory per step.
    Buffered,
    /// Figure-3(c) style: running max/sum scans — every FIFO depth 2,
    /// O(1) memory per step.
    MemoryFree,
    /// FLASH-D style: hidden-division log-sum-exp scan plus output EMA
    /// — every FIFO depth 2, O(1) memory per step, no divider node.
    FlashD,
}

impl DecodeKind {
    /// Every mapping, buffered (contrast) first.
    pub const ALL: [DecodeKind; 3] = [
        DecodeKind::Buffered,
        DecodeKind::MemoryFree,
        DecodeKind::FlashD,
    ];

    /// Stable lowercase name (reports, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            DecodeKind::Buffered => "buffered",
            DecodeKind::MemoryFree => "memfree",
            DecodeKind::FlashD => "flashd",
        }
    }
}

impl std::fmt::Display for DecodeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Long-FIFO depth one decode step needs at cache length `len` — the
/// causal-aware bound ([`super::causal::long_fifo_bound`] with
/// `visible = len`). `DepthPolicy::Inferred` re-derives exactly this
/// from the step graph's structure.
pub fn step_long_fifo_bound(kind: DecodeKind, len: usize) -> usize {
    match kind {
        DecodeKind::Buffered => len + 2,
        DecodeKind::MemoryFree | DecodeKind::FlashD => 2,
    }
}

/// Build one decode step: query row `q` against `len = keys.len()`
/// cached K/V rows. The returned graph emits exactly one output row.
pub fn build_step(
    kind: DecodeKind,
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let k: Vec<&[f32]> = keys.iter().map(Vec::as_slice).collect();
    let v: Vec<&[f32]> = values.iter().map(Vec::as_slice).collect();
    build_step_rows(kind, q, &k, &v, policy)
}

/// [`build_step`] over borrowed rows — the entry point the paged
/// KV-cache path uses: a [`BlockPool::view`] gather walks a session's
/// block table and hands the row slices straight here, so the step
/// graph is *identical* to the contiguous build (same sources, same
/// element order, bit-identical output).
pub fn build_step_rows(
    kind: DecodeKind,
    q: &[f32],
    keys: &[&[f32]],
    values: &[&[f32]],
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let len = keys.len();
    let d = q.len();
    let mut g = GraphBuilder::new();
    let mut sc = g.root();
    let out = build_step_rows_into(&mut sc, kind, q, keys, values)?;
    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n: len,
        d,
    })
}

/// The decode-step pipeline, buildable into any scope — the composition
/// point for the multi-lane serving engines: one scheduling iteration of
/// the continuous-batching server instantiates one of these per active
/// session inside its own lane scope (see
/// [`super::multihead::build_decode_lanes`]), exactly the way attention
/// heads compose spatially. Inputs are validated the same way
/// [`build_step`] validates them.
pub fn build_step_into(
    sc: &mut Scope<'_>,
    kind: DecodeKind,
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
) -> Result<SinkHandle> {
    let k: Vec<&[f32]> = keys.iter().map(Vec::as_slice).collect();
    let v: Vec<&[f32]> = values.iter().map(Vec::as_slice).collect();
    build_step_rows_into(sc, kind, q, &k, &v)
}

/// [`build_step_into`] over borrowed rows. The K/V sources replay the
/// gathered row sequence — whether it came from contiguous `Vec`s or a
/// block-table walk is invisible to the graph, which is exactly why
/// paged and contiguous decode are bit-identical.
pub fn build_step_rows_into(
    sc: &mut Scope<'_>,
    kind: DecodeKind,
    q: &[f32],
    keys: &[&[f32]],
    values: &[&[f32]],
) -> Result<SinkHandle> {
    let len = keys.len();
    let d = q.len();
    if len == 0 {
        return Err(Error::Graph(
            "decode step needs at least one cached K/V row".into(),
        ));
    }
    if d == 0 {
        return Err(Error::Graph("decode step: query row is empty".into()));
    }
    if values.len() != len {
        return Err(Error::Graph(format!(
            "decode step: {} keys but {} values",
            len,
            values.len()
        )));
    }
    if let Some(row) = keys.iter().chain(values.iter()).find(|r| r.len() != d) {
        return Err(Error::Graph(format!(
            "decode step: cached row has dim {}, query has {}",
            row.len(),
            d
        )));
    }
    let scale = 1.0 / (d as f32).sqrt();

    // One query row, replayed once per cached key; K/V replay from the
    // cache (resident operands — stateless, reset-safe sources).
    let q_rows = sc.source_vec("src_q", vec![Elem::vector(q)])?;
    let q_rep = sc.repeat("rep_q", q_rows, len)?;
    let k: Vec<Elem> = keys.iter().map(|r| Elem::vector(r)).collect();
    let k_cols = sc.source_gen("src_k", len as u64, move |j| k[j as usize].clone())?;
    let s = sc.zip("qk_dot", [q_rep, k_cols], move |xs| {
        Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
    })?;
    let v: Vec<Elem> = values.iter().map(|r| Elem::vector(r)).collect();

    match kind {
        DecodeKind::MemoryFree => {
            // Eq. 4: running max → (Δ, e) per cached key.
            let neg_inf = Elem::Pair(f32::NEG_INFINITY, f32::NEG_INFINITY);
            let de = sc.scan(
                "run_max",
                s,
                len,
                neg_inf,
                |st, x| {
                    let (_, m_old) = st.pair();
                    let m_new = m_old.max(x.scalar());
                    Elem::Pair(m_old, m_new)
                },
                |st, x| {
                    let (m_old, m_new) = st.pair();
                    let delta = (m_old - m_new).exp();
                    let e = (x.scalar() - m_new).exp();
                    Elem::Pair(delta, e)
                },
            )?;
            let [de_r, de_l] = sc.broadcast("bc_de", de, ["de_r", "de_l"])?;

            // Eq. 5 scalar: r ← r·Δ + e.
            let r_run = sc.scan(
                "run_sum",
                de_r,
                len,
                Elem::Scalar(0.0),
                |st, x| {
                    let (delta, e) = x.pair();
                    Elem::Scalar(st.scalar() * delta + e)
                },
                |st, _| st.clone(),
            )?;
            let r = sc.last_of("last_r", r_run, len)?;

            // Eq. 5 vector: l⃗ ← l⃗·Δ + e·v⃗_j.
            let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
            let dev = sc.zip("zip_v", [de_l, v_cols], |xs| {
                Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
            })?;
            let l_run = sc.scan(
                "run_out",
                dev,
                len,
                Elem::from(vec![0.0f32; d]),
                |st, x| {
                    let (delta, e) = x.as_tuple()[0].pair();
                    let vv = x.as_tuple()[1].as_vector();
                    Elem::from(
                        st.as_vector()
                            .iter()
                            .zip(vv)
                            .map(|(acc, v)| acc * delta + e * v)
                            .collect::<Vec<_>>(),
                    )
                },
                |st, _| st.clone(),
            )?;
            let l = sc.last_of("last_l", l_run, len)?;

            // Eq. 6: o⃗_t = l⃗ / r.
            let o = sc.zip("div", [l, r], |xs| {
                let r = xs[1].scalar();
                Elem::from(xs[0].as_vector().iter().map(|x| x / r).collect::<Vec<_>>())
            })?;
            sc.sink("sink_o", o, Some(1))
        }
        DecodeKind::Buffered => {
            // Figure-2 shape at window `len`: the bypass must hold the
            // whole visible prefix while the row sum reduces.
            let e = sc.map("exp", s, |x| Elem::Scalar(x.scalar().exp()))?;
            let [e_sum, e_bypass] = sc.broadcast("bc_e", e, ["e_sum", "e_bypass"])?;
            let sigma = sc.reduce("row_sum", e_sum, len, 0.0, |a, b| a + b)?;
            let sigma_rep = sc.repeat("rep_sigma", sigma, len)?;
            let p = sc.zip("div", [e_bypass, sigma_rep], |xs| {
                Elem::Scalar(xs[0].scalar() / xs[1].scalar())
            })?;
            let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
            let pv = sc.zip("pv_mul", [p, v_cols], |xs| {
                let p = xs[0].scalar();
                Elem::from(xs[1].as_vector().iter().map(|v| p * v).collect::<Vec<_>>())
            })?;
            let o = sc.mem_reduce("pv_acc", pv, len, vec![0.0; d], |acc, x| {
                acc.iter().zip(x.as_vector()).map(|(a, b)| a + b).collect()
            })?;
            sc.sink("sink_o", o, Some(1))
        }
        DecodeKind::FlashD => {
            // FLASH-D: the running log-sum-exp emits already-normalized
            // weights, the output is an exact EMA — no divider node.
            // Same fold helpers as the prefill graph and the sequential
            // reference, so all three execute identical f32 sequences.
            let wgt = sc.scan(
                "run_lse",
                s,
                len,
                Elem::Scalar(f32::NEG_INFINITY),
                |st, x| Elem::Scalar(super::flashd::lse_fold(st.scalar(), x.scalar())),
                |st, x| Elem::Scalar(super::flashd::hidden_weight(x.scalar(), st.scalar())),
            )?;
            let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
            let wv = sc.zip("zip_wv", [wgt, v_cols], |xs| {
                Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
            })?;
            let o_run = sc.scan(
                "run_ema",
                wv,
                len,
                Elem::from(vec![0.0f32; d]),
                |st, x| {
                    let wgt = x.as_tuple()[0].scalar();
                    let vv = x.as_tuple()[1].as_vector();
                    Elem::from(
                        st.as_vector()
                            .iter()
                            .zip(vv)
                            .map(|(o, v)| o + wgt * (v - o))
                            .collect::<Vec<_>>(),
                    )
                },
                |st, _| st.clone(),
            )?;
            let o = sc.last_of("last_o", o_run, len)?;
            sc.sink("sink_o", o, Some(1))
        }
    }
}

/// The serving steady state as a one-shot graph: the *last* decode step
/// of workload `w` (query row N−1 against the full K/V cache, the
/// memory-free mapping). This is what [`super::Variant::Decode`]
/// builds, so the whole experiment/test grid exercises decode through
/// the ordinary variant machinery.
pub fn build_last_row(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_step(DecodeKind::MemoryFree, &w.q[w.n - 1], &w.k, &w.v, policy)
}

// ---------------------------------------------------------------------
// Resumable prefill chunks
// ---------------------------------------------------------------------

/// The online-softmax running state `(m, r, ℓ⃗)` of one partially
/// scanned attention row — exactly the state the memory-free mapping's
/// three `Scan`s carry element to element (Eq. 4–5), lifted out of the
/// graph so a prefill row can stop after any key and resume in a later
/// wave.
///
/// Bit-exactness across the split is structural: the scans are *pure*
/// f32 recurrences, so seeding a fresh segment's scan inits with the
/// carry reproduces exactly the state sequence the unsplit scan would
/// have traversed — the same "reorder the arithmetic, change nothing
/// numerically" move the paper applies to the row reductions, applied
/// here across waves. [`SoftmaxCarry::fresh`] is the ordinary inits
/// `(−∞, 0, 0⃗)`, so an unsplit row is the degenerate one-segment case.
#[derive(Clone, Debug, PartialEq)]
pub struct SoftmaxCarry {
    /// Running maximum `m` over the scanned scores.
    pub m: f32,
    /// Running rescaled exponential sum `r`.
    pub r: f32,
    /// Running rescaled output accumulator `ℓ⃗` (head dimension wide).
    pub acc: Vec<f32>,
}

impl SoftmaxCarry {
    /// The state before any key: `(−∞, 0, 0⃗)` — identical to the scan
    /// inits of the unsplit memory-free step.
    pub fn fresh(d: usize) -> Self {
        SoftmaxCarry {
            m: f32::NEG_INFINITY,
            r: 0.0,
            acc: vec![0.0; d],
        }
    }

    /// Whether no key has been folded in yet.
    pub fn is_fresh(&self) -> bool {
        self.m == f32::NEG_INFINITY && self.r == 0.0 && self.acc.iter().all(|&x| x == 0.0)
    }

    /// Flatten into the `[m, r, ℓ_0 … ℓ_{d−1}]` row a non-final chunk
    /// segment sinks (the carry-state wire format between waves).
    pub fn pack(&self) -> Vec<f32> {
        let mut row = Vec::with_capacity(2 + self.acc.len());
        row.push(self.m);
        row.push(self.r);
        row.extend_from_slice(&self.acc);
        row
    }

    /// Parse a packed `[m, r, ℓ…]` carry row (the inverse of
    /// [`Self::pack`]).
    pub fn unpack(row: &[f32]) -> Result<SoftmaxCarry> {
        if row.len() < 3 {
            return Err(Error::Coordinator(format!(
                "carry row has {} values, need at least 3 (m, r, ℓ⃗)",
                row.len()
            )));
        }
        Ok(SoftmaxCarry {
            m: row[0],
            r: row[1],
            acc: row[2..].to_vec(),
        })
    }
}

/// Build one resumable chunk segment of a memory-free attention row:
/// query `q` against the key span `keys`/`values` (a contiguous slice
/// of the row's visible cache, in cache order), resuming from `carry`.
///
/// * `finalize = true` — this segment reaches the row's last visible
///   key: the graph is the ordinary memory-free step pipeline with its
///   scan inits seeded from the carry, and the sink emits the finished
///   output row `o⃗ = ℓ⃗ / r` (width `d`). With a fresh carry and the
///   full key span this is *exactly* [`build_step_rows_into`]'s
///   memory-free graph.
/// * `finalize = false` — the row stops mid-scan: the running-max scan
///   emits `(Δ, e, m)` triples so the final `m` can be sampled beside
///   `r` and `ℓ⃗`, and the sink emits the packed carry row
///   `[m, r, ℓ_0 … ℓ_{d−1}]` (width `d + 2`) for the next wave to
///   resume from. Δ and e are computed by the same expressions either
///   way, so the downstream recurrences see bit-identical values.
///
/// Every FIFO stays depth 2 — a chunk segment keeps the paper's O(1)
/// intermediate memory however long the row or short the segment.
pub fn build_chunk_segment_into(
    sc: &mut Scope<'_>,
    q: &[f32],
    keys: &[&[f32]],
    values: &[&[f32]],
    carry: &SoftmaxCarry,
    finalize: bool,
) -> Result<SinkHandle> {
    let len = keys.len();
    let d = q.len();
    if len == 0 {
        return Err(Error::Graph(
            "chunk segment needs at least one cached K/V row".into(),
        ));
    }
    if d == 0 {
        return Err(Error::Graph("chunk segment: query row is empty".into()));
    }
    if values.len() != len {
        return Err(Error::Graph(format!(
            "chunk segment: {} keys but {} values",
            len,
            values.len()
        )));
    }
    if let Some(row) = keys.iter().chain(values.iter()).find(|r| r.len() != d) {
        return Err(Error::Graph(format!(
            "chunk segment: cached row has dim {}, query has {}",
            row.len(),
            d
        )));
    }
    if carry.acc.len() != d {
        return Err(Error::Graph(format!(
            "chunk segment: carry accumulator has dim {}, query has {}",
            carry.acc.len(),
            d
        )));
    }
    let scale = 1.0 / (d as f32).sqrt();

    let q_rows = sc.source_vec("src_q", vec![Elem::vector(q)])?;
    let q_rep = sc.repeat("rep_q", q_rows, len)?;
    let k: Vec<Elem> = keys.iter().map(|r| Elem::vector(r)).collect();
    let k_cols = sc.source_gen("src_k", len as u64, move |j| k[j as usize].clone())?;
    let s = sc.zip("qk_dot", [q_rep, k_cols], move |xs| {
        Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
    })?;
    let v: Vec<Elem> = values.iter().map(|r| Elem::vector(r)).collect();
    let seed_max = Elem::Pair(carry.m, carry.m);

    if finalize {
        // The memory-free step pipeline, inits seeded from the carry.
        let de = sc.scan(
            "run_max",
            s,
            len,
            seed_max,
            |st, x| {
                let (_, m_old) = st.pair();
                let m_new = m_old.max(x.scalar());
                Elem::Pair(m_old, m_new)
            },
            |st, x| {
                let (m_old, m_new) = st.pair();
                let delta = (m_old - m_new).exp();
                let e = (x.scalar() - m_new).exp();
                Elem::Pair(delta, e)
            },
        )?;
        let [de_r, de_l] = sc.broadcast("bc_de", de, ["de_r", "de_l"])?;
        let r_run = sc.scan(
            "run_sum",
            de_r,
            len,
            Elem::Scalar(carry.r),
            |st, x| {
                let (delta, e) = x.pair();
                Elem::Scalar(st.scalar() * delta + e)
            },
            |st, _| st.clone(),
        )?;
        let r = sc.last_of("last_r", r_run, len)?;
        let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
        let dev = sc.zip("zip_v", [de_l, v_cols], |xs| {
            Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
        })?;
        let l_run = sc.scan(
            "run_out",
            dev,
            len,
            Elem::from(carry.acc.clone()),
            |st, x| {
                let (delta, e) = x.as_tuple()[0].pair();
                let vv = x.as_tuple()[1].as_vector();
                Elem::from(
                    st.as_vector()
                        .iter()
                        .zip(vv)
                        .map(|(acc, v)| acc * delta + e * v)
                        .collect::<Vec<_>>(),
                )
            },
            |st, _| st.clone(),
        )?;
        let l = sc.last_of("last_l", l_run, len)?;
        let o = sc.zip("div", [l, r], |xs| {
            let r = xs[1].scalar();
            Elem::from(xs[0].as_vector().iter().map(|x| x / r).collect::<Vec<_>>())
        })?;
        sc.sink("sink_o", o, Some(1))
    } else {
        // Mid-row stop: the running-max scan emits (Δ, e, m) so the
        // final m can ride to the carry sink beside r and ℓ⃗. Δ and e
        // are the same expressions as above — the r/ℓ⃗ recurrences see
        // bit-identical operands, only the container differs.
        let dem = sc.scan(
            "run_max",
            s,
            len,
            seed_max,
            |st, x| {
                let (_, m_old) = st.pair();
                let m_new = m_old.max(x.scalar());
                Elem::Pair(m_old, m_new)
            },
            |st, x| {
                let (m_old, m_new) = st.pair();
                let delta = (m_old - m_new).exp();
                let e = (x.scalar() - m_new).exp();
                Elem::from(vec![delta, e, m_new])
            },
        )?;
        let [de_r, de_l, de_m] = sc.broadcast("bc_de", dem, ["de_r", "de_l", "de_m"])?;
        let r_run = sc.scan(
            "run_sum",
            de_r,
            len,
            Elem::Scalar(carry.r),
            |st, x| {
                let t = x.as_vector();
                Elem::Scalar(st.scalar() * t[0] + t[1])
            },
            |st, _| st.clone(),
        )?;
        let r = sc.last_of("last_r", r_run, len)?;
        let m_run = sc.map("m_of", de_m, |x| Elem::Scalar(x.as_vector()[2]))?;
        let m = sc.last_of("last_m", m_run, len)?;
        let v_cols = sc.source_gen("src_v", len as u64, move |j| v[j as usize].clone())?;
        let dev = sc.zip("zip_v", [de_l, v_cols], |xs| {
            Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
        })?;
        let l_run = sc.scan(
            "run_out",
            dev,
            len,
            Elem::from(carry.acc.clone()),
            |st, x| {
                let t = x.as_tuple()[0].as_vector();
                let vv = x.as_tuple()[1].as_vector();
                Elem::from(
                    st.as_vector()
                        .iter()
                        .zip(vv)
                        .map(|(acc, v)| acc * t[0] + t[1] * v)
                        .collect::<Vec<_>>(),
                )
            },
            |st, _| st.clone(),
        )?;
        let l = sc.last_of("last_l", l_run, len)?;
        let packed = sc.zip("pack_carry", [m, r, l], |xs| {
            let acc = xs[2].as_vector();
            let mut row = Vec::with_capacity(2 + acc.len());
            row.push(xs[0].scalar());
            row.push(xs[1].scalar());
            row.extend_from_slice(acc);
            Elem::from(row)
        })?;
        sc.sink("sink_c", packed, Some(1))
    }
}

/// One completed decode step.
#[derive(Clone, Debug)]
pub struct DecodeStepOutcome {
    /// 0-based step index within the session.
    pub step: usize,
    /// The attention output row o⃗_t.
    pub row: Vec<f32>,
    /// The step graph's run summary (cycles, occupancy, depth report).
    pub summary: RunSummary,
}

/// An autoregressive decode session: owns the growing K/V cache, builds
/// and runs one step graph per token, and accumulates the output rows.
pub struct DecodeSession {
    kind: DecodeKind,
    d: usize,
    policy: DepthPolicy,
    mode: Option<SchedulerMode>,
    threads: Option<usize>,
    window: Option<usize>,
    keys: Vec<Vec<f32>>,
    values: Vec<Vec<f32>>,
    outputs: Matrix,
}

impl DecodeSession {
    /// New session for head dimension `d` with inferred FIFO depths.
    pub fn new(kind: DecodeKind, d: usize) -> Self {
        Self::with_policy(kind, d, DepthPolicy::Inferred)
    }

    /// New sliding-window session: each step attends only the last `w`
    /// cached rows (the contiguous twin of a windowed paged session;
    /// the cache itself still grows — only the paged variant evicts).
    pub fn new_windowed(kind: DecodeKind, d: usize, w: usize) -> Self {
        assert!(w >= 1, "window needs a width of at least 1");
        let mut s = Self::new(kind, d);
        s.window = Some(w);
        s
    }

    /// New session under an explicit depth policy.
    pub fn with_policy(kind: DecodeKind, d: usize, policy: DepthPolicy) -> Self {
        assert!(d >= 1, "head dimension must be at least 1");
        DecodeSession {
            kind,
            d,
            policy,
            mode: None,
            threads: None,
            window: None,
            keys: Vec::new(),
            values: Vec::new(),
            outputs: Vec::new(),
        }
    }

    /// Force a scheduler mode on every step engine (differential tests;
    /// the default is the engine's own default, i.e. `SDPA_SCHED`).
    pub fn set_scheduler_mode(&mut self, mode: SchedulerMode) {
        self.mode = Some(mode);
    }

    /// Pin the worker-thread count on every step engine (the default is
    /// the engine's own default, i.e. `SDPA_THREADS`). Results are
    /// bit-identical for every value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = Some(threads);
    }

    /// The step mapping this session uses.
    pub fn kind(&self) -> DecodeKind {
        self.kind
    }

    /// Sliding-window width, if any.
    pub fn window(&self) -> Option<usize> {
        self.window
    }

    /// Rows the next step graph will stream: the whole cache, capped
    /// at the window.
    fn visible(&self) -> usize {
        match self.window {
            Some(w) => self.keys.len().min(w),
            None => self.keys.len(),
        }
    }

    /// Tokens decoded so far (== cached K/V rows == output rows).
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// Whether no token has been decoded yet.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Output rows accumulated so far, one per step.
    pub fn outputs(&self) -> &Matrix {
        &self.outputs
    }

    /// The cached key rows (one per decoded token).
    pub fn keys(&self) -> &[Vec<f32>] {
        &self.keys
    }

    /// The cached value rows (one per decoded token).
    pub fn values(&self) -> &[Vec<f32>] {
        &self.values
    }

    /// Validate one step's row shapes and append `(k, v)` to the cache —
    /// the first half of a step. The caller either runs the step graph
    /// and [`Self::commit_row`]s the result, or [`Self::unstage`]s on
    /// failure so the cache is left exactly as it was. The serving lane
    /// pool uses this split to run many sessions' staged steps in one
    /// engine (see `coordinator::sessions::SessionTable::step_wave`).
    pub(crate) fn stage(&mut self, q: &[f32], k: Vec<f32>, v: Vec<f32>) -> Result<()> {
        for (what, len) in [("q", q.len()), ("k", k.len()), ("v", v.len())] {
            if len != self.d {
                return Err(Error::Graph(format!(
                    "decode step {}: {what} has dim {}, session expects {}",
                    self.keys.len(),
                    len,
                    self.d
                )));
            }
        }
        self.keys.push(k);
        self.values.push(v);
        Ok(())
    }

    /// Undo the most recent [`Self::stage`] (a failed step must not
    /// corrupt the session: a retry sees the pre-step state).
    pub(crate) fn unstage(&mut self) {
        self.keys.pop();
        self.values.pop();
    }

    /// Record the staged step's output row, completing the step.
    pub(crate) fn commit_row(&mut self, row: Vec<f32>) {
        self.outputs.push(row);
    }

    /// Decode one token: append `(k, v)` to the cache, stream `q`
    /// against it, return the output row and the step's run summary.
    pub fn step(&mut self, q: Vec<f32>, k: Vec<f32>, v: Vec<f32>) -> Result<DecodeStepOutcome> {
        self.stage(&q, k, v)?;
        // A windowed session streams only the last min(len, W) rows —
        // the same span a windowed paged gather produces.
        let start = self.keys.len() - self.visible();
        let result = build_step(
            self.kind,
            &q,
            &self.keys[start..],
            &self.values[start..],
            self.policy,
        )
        .and_then(|mut built| {
            if let Some(mode) = self.mode {
                built.engine.set_scheduler_mode(mode);
            }
            if let Some(th) = self.threads {
                built.engine.set_threads(th);
            }
            built.run()
        });
        let (rows, summary) = match result {
            Ok(ok) => ok,
            Err(e) => {
                // A failed step (e.g. deadlock under an undersized
                // explicit plan) must not corrupt the session: unwind
                // the cache so a retry sees the pre-step state.
                self.unstage();
                return Err(e);
            }
        };
        let row = rows.into_iter().next().expect("decode step emits one row");
        self.commit_row(row.clone());
        Ok(DecodeStepOutcome {
            step: self.keys.len() - 1,
            row,
            summary,
        })
    }
}

/// An autoregressive decode session over the **paged** KV cache: the
/// session's rows live in fixed-size blocks of a shared [`BlockPool`],
/// addressed through a private [`BlockTable`]. The pool is passed into
/// every mutating call (the coordinator owns one pool for all
/// sessions), so the session itself stays plain data.
///
/// Semantics relative to [`DecodeSession`]:
///
/// * **Steps are bit-identical.** A step gathers the table in row
///   order ([`BlockPool::view`]) and feeds the same slices to the same
///   graph builder; block boundaries are invisible to the pipeline.
/// * **Forking** ([`Self::fork`]) shares the whole current cache with
///   a child session at zero copies (refcounts, CoW on the tail block
///   at the first divergent append). The child's transcript starts
///   empty: it records only rows the child itself decodes.
/// * **Preemption** ([`Self::preempt`]) swaps the cache out of the
///   bounded pool; the next step (or an explicit [`Self::restore`])
///   swaps it back in bit-exactly, so a preempt/requeue cycle cannot
///   perturb the transcript. While the pool lacks room, staging and
///   restoring return [`Error::AdmissionDeferred`] for the caller to
///   requeue.
pub struct PagedDecodeSession {
    kind: DecodeKind,
    d: usize,
    policy: DepthPolicy,
    mode: Option<SchedulerMode>,
    threads: Option<usize>,
    table: BlockTable,
    /// `Some` while preempted (cache swapped out of the pool). The
    /// table is empty exactly when this is `Some` (or the session has
    /// decoded nothing).
    swapped: Option<SwappedKv>,
    /// Undo token of the currently staged step (any pending
    /// copy-on-write reference or evicted row rides in it until the
    /// step commits or unwinds — see [`BlockPool::append_row`]).
    staged: Option<AppendUndo>,
    outputs: Matrix,
}

impl PagedDecodeSession {
    /// New paged session for head dimension `d`, inferred FIFO depths.
    pub fn new(kind: DecodeKind, d: usize) -> Self {
        Self::with_policy(kind, d, DepthPolicy::Inferred)
    }

    /// New sliding-window paged session: each step attends only the
    /// last `w` cached rows, and the block table is a ring that evicts
    /// older rows in place — the session never holds more than
    /// ⌈w/block_size⌉ blocks, however long it runs.
    pub fn new_windowed(kind: DecodeKind, d: usize, w: usize) -> Self {
        let mut s = Self::new(kind, d);
        s.table = BlockTable::windowed(w);
        s
    }

    /// New paged session under an explicit depth policy.
    pub fn with_policy(kind: DecodeKind, d: usize, policy: DepthPolicy) -> Self {
        assert!(d >= 1, "head dimension must be at least 1");
        PagedDecodeSession {
            kind,
            d,
            policy,
            mode: None,
            threads: None,
            table: BlockTable::new(),
            swapped: None,
            staged: None,
            outputs: Vec::new(),
        }
    }

    /// Force a scheduler mode on every step engine (differential tests;
    /// the default is the engine's own default, i.e. `SDPA_SCHED`).
    pub fn set_scheduler_mode(&mut self, mode: SchedulerMode) {
        self.mode = Some(mode);
    }

    /// Pin the worker-thread count on every step engine (the default is
    /// the engine's own default, i.e. `SDPA_THREADS`). Results are
    /// bit-identical for every value.
    pub fn set_threads(&mut self, threads: usize) {
        self.threads = Some(threads);
    }

    /// The step mapping this session uses.
    pub fn kind(&self) -> DecodeKind {
        self.kind
    }

    /// Sliding-window width, if any.
    pub fn window(&self) -> Option<usize> {
        self.table.window()
    }

    /// Tokens decoded so far (the logical transcript length — for a
    /// windowed session this keeps growing past the resident rows).
    pub fn len(&self) -> usize {
        match &self.swapped {
            Some(s) => s.len,
            None => self.table.len(),
        }
    }

    /// Whether no token has been decoded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Output rows accumulated so far, one per step.
    pub fn outputs(&self) -> &Matrix {
        &self.outputs
    }

    /// The session's block table (empty while preempted).
    pub fn table(&self) -> &BlockTable {
        &self.table
    }

    /// Whether the cache is currently swapped out of the pool.
    pub fn is_preempted(&self) -> bool {
        self.swapped.is_some()
    }

    /// Fork: a child session sharing every cached block (no copies;
    /// refcounted, CoW on first divergent append). The child inherits
    /// kind, head dimension, depth policy, scheduler mode, and thread
    /// count, and starts with an empty transcript. The parent must be
    /// resident.
    pub fn fork(&self, pool: &mut BlockPool) -> Result<PagedDecodeSession> {
        if self.is_preempted() {
            return Err(Error::Coordinator(
                "cannot fork a preempted session (restore it first)".into(),
            ));
        }
        Ok(PagedDecodeSession {
            kind: self.kind,
            d: self.d,
            policy: self.policy,
            mode: self.mode,
            threads: self.threads,
            table: pool.fork(&self.table),
            swapped: None,
            staged: None,
            outputs: Vec::new(),
        })
    }

    /// Swap the cache out of the pool (freeing every block this
    /// session exclusively owns) so another session can run. No-op if
    /// already preempted or empty.
    pub fn preempt(&mut self, pool: &mut BlockPool) {
        debug_assert!(
            self.staged.is_none(),
            "preempting a session with a step staged (waves exclude staged members)"
        );
        if self.swapped.is_some() || self.table.is_empty() {
            return;
        }
        self.swapped = Some(pool.swap_out(&mut self.table));
    }

    /// Swap a preempted cache back into the pool (bit-exact; sharing
    /// is not re-established). [`Error::AdmissionDeferred`] when the
    /// pool lacks room — the swap is kept and the call can be retried.
    pub fn restore(&mut self, pool: &mut BlockPool) -> Result<()> {
        let Some(swapped) = &self.swapped else {
            return Ok(());
        };
        pool.swap_in(&mut self.table, swapped)?;
        self.swapped = None;
        Ok(())
    }

    /// Validate one step's row shapes and append `(k, v)` to the block
    /// table — the first half of a step (see [`DecodeSession::stage`]).
    /// [`Error::AdmissionDeferred`] when the pool has no block for the
    /// append; the table is left exactly as it was. The rows are
    /// copied into the pool once here (the pool owns its rows; the
    /// borrowed request stays intact so a deferred step can requeue
    /// copy-free) — a deliberate O(d) cost per served step, dwarfed by
    /// the step's engine run.
    pub(crate) fn stage(
        &mut self,
        pool: &mut BlockPool,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<()> {
        if self.is_preempted() {
            return Err(Error::Coordinator(
                "cannot stage a step on a preempted session (restore it first)".into(),
            ));
        }
        for (what, len) in [("q", q.len()), ("k", k.len()), ("v", v.len())] {
            if len != self.d {
                return Err(Error::Graph(format!(
                    "decode step {}: {what} has dim {}, session expects {}",
                    self.table.len(),
                    len,
                    self.d
                )));
            }
        }
        debug_assert!(
            self.staged.is_none(),
            "stage without resolving the previous staged step"
        );
        self.staged = Some(pool.append_row(&mut self.table, k.to_vec(), v.to_vec())?);
        Ok(())
    }

    /// Undo the most recent [`Self::stage`] (a failed step must not
    /// corrupt the session) — including reverting a copy-on-write
    /// split or a ring eviction, so block accounting, sharing, and
    /// content end exactly as they were.
    pub(crate) fn unstage(&mut self, pool: &mut BlockPool) {
        if let Some(undo) = self.staged.take() {
            pool.undo_append(&mut self.table, undo);
        }
    }

    /// Record the staged step's output row, completing the step (and
    /// resolving any pending copy-on-write or eviction the stage made).
    pub(crate) fn commit_row(&mut self, pool: &mut BlockPool, row: Vec<f32>) {
        if let Some(undo) = self.staged.take() {
            pool.commit_append(undo);
        }
        self.outputs.push(row);
    }

    /// Append one prompt row's `(k, v)` during chunked prefill. Unlike
    /// [`Self::stage`], the undo token is handed to the caller: one
    /// wave may append several prompt rows to one session, so the wave
    /// (not the session) owns the transaction. Shapes are validated by
    /// the session table at prompt admission.
    pub(crate) fn append_prefill_row(
        &mut self,
        pool: &mut BlockPool,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<AppendUndo> {
        debug_assert!(
            self.staged.is_none(),
            "prefill appends never overlap a staged decode step"
        );
        if self.is_preempted() {
            return Err(Error::Coordinator(
                "cannot prefill a preempted session (restore it first)".into(),
            ));
        }
        pool.append_row(&mut self.table, k, v)
    }

    /// Revert one [`Self::append_prefill_row`] of a failed wave. Undos
    /// must be applied most-recent-first per session.
    pub(crate) fn undo_prefill_append(&mut self, pool: &mut BlockPool, undo: AppendUndo) {
        pool.undo_append(&mut self.table, undo);
    }

    /// Record one finished prefill row's output (the wave commits the
    /// matching appends itself, via the undo tokens it holds).
    pub(crate) fn push_output_row(&mut self, row: Vec<f32>) {
        self.outputs.push(row);
    }

    /// Build and run the already-staged step alone in its own engine,
    /// returning the output row and summary *without* committing — the
    /// caller commits ([`Self::commit_row`]) or unwinds
    /// ([`Self::unstage`]); this borrows the pool immutably, so it can
    /// do neither itself.
    pub(crate) fn run_staged(
        &mut self,
        pool: &BlockPool,
        q: &[f32],
    ) -> Result<(Vec<f32>, RunSummary)> {
        let result = {
            let view = pool.view(&self.table);
            build_step_rows(self.kind, q, &view.keys, &view.values, self.policy)
        }
        .and_then(|mut built| {
            if let Some(mode) = self.mode {
                built.engine.set_scheduler_mode(mode);
            }
            if let Some(th) = self.threads {
                built.engine.set_threads(th);
            }
            built.run()
        });
        let (rows, summary) = result?;
        let row = rows.into_iter().next().expect("decode step emits one row");
        Ok((row, summary))
    }

    /// Decode one token against the paged cache: restore if preempted,
    /// append `(k, v)`, stream `q` against the gathered table, return
    /// the output row. A failed step (including
    /// [`Error::AdmissionDeferred`] from a full pool) leaves the
    /// session exactly as it was, so the caller can retry.
    pub fn step(
        &mut self,
        pool: &mut BlockPool,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
    ) -> Result<DecodeStepOutcome> {
        self.restore(pool)?;
        self.stage(pool, &q, &k, &v)?;
        match self.run_staged(pool, &q) {
            Ok((row, summary)) => {
                self.commit_row(pool, row.clone());
                Ok(DecodeStepOutcome {
                    step: self.table.len() - 1,
                    row,
                    summary,
                })
            }
            Err(e) => {
                self.unstage(pool);
                Err(e)
            }
        }
    }

    /// Retire the session: release every block reference (resolving any
    /// pending copy-on-write first) and return the transcript.
    pub fn close(mut self, pool: &mut BlockPool) -> Matrix {
        if let Some(undo) = self.staged.take() {
            pool.commit_append(undo);
        }
        pool.release(&mut self.table);
        self.outputs
    }
}

/// Run a full autoregressive pass over `w` — step `t` feeds
/// `(q_t, k_t, v_t)` — and return the N output rows. Must agree with
/// the causal prefill references row for row (the decode-chain half of
/// the differential conformance suite).
pub fn decode_workload(kind: DecodeKind, w: &Workload) -> Result<Matrix> {
    let mut session = DecodeSession::new(kind, w.d);
    for t in 0..w.n {
        session.step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())?;
    }
    Ok(session.outputs)
}

#[cfg(test)]
mod tests {
    use super::super::reference::{
        assert_close, sdpa_f64_masked, sdpa_flashd_f32_masked, sdpa_online_f32_masked,
    };
    use super::super::workload::Mask;
    use super::super::{FifoPlan, Variant};
    use super::*;
    use crate::sim::Capacity;

    #[test]
    fn memfree_chain_matches_online_causal_reference_tightly() {
        let w = Workload::random(12, 8, 0xDEC1);
        let chain = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        // Same f32 operations in the same order as the oracle.
        assert_close(
            &chain,
            &sdpa_online_f32_masked(&w, &Mask::Causal),
            1e-6,
            "decode chain vs online causal",
        );
        assert_close(
            &chain,
            &sdpa_f64_masked(&w, &Mask::Causal),
            1e-4,
            "decode chain vs f64 causal",
        );
    }

    #[test]
    fn buffered_chain_matches_f64_causal() {
        let w = Workload::random(10, 4, 0xDEC2);
        let chain = decode_workload(DecodeKind::Buffered, &w).unwrap();
        assert_close(
            &chain,
            &sdpa_f64_masked(&w, &Mask::Causal),
            1e-4,
            "buffered decode chain vs f64 causal",
        );
    }

    #[test]
    fn flashd_chain_matches_the_hidden_division_causal_reference_tightly() {
        let w = Workload::random(12, 8, 0xDEC6);
        let chain = decode_workload(DecodeKind::FlashD, &w).unwrap();
        // The step graph folds scores through the same lse_fold /
        // hidden_weight helpers as the sequential reference, in the
        // same order — agreement is effectively structural.
        assert_close(
            &chain,
            &sdpa_flashd_f32_masked(&w, &Mask::Causal),
            1e-6,
            "flashd decode chain vs hidden-division causal",
        );
        assert_close(
            &chain,
            &sdpa_f64_masked(&w, &Mask::Causal),
            1e-4,
            "flashd decode chain vs f64 causal",
        );
    }

    #[test]
    fn flashd_step_has_no_divider_and_all_depth_2_fifos() {
        let w = Workload::random(16, 4, 0xDEC7);
        for len in [1usize, 4, 16] {
            let p = w.prefix(len);
            let mut built = build_step(
                DecodeKind::FlashD,
                &p.q[len - 1],
                &p.k,
                &p.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            for c in built.engine.depth_report() {
                assert!(!c.is_long, "flashd len={len}: '{}'", c.name);
                assert_eq!(c.capacity, Capacity::Bounded(2), "len={len}: '{}'", c.name);
            }
            let (_, summary) = built.run().unwrap();
            assert!(
                summary.node_fires.iter().all(|(name, _)| name != "div"),
                "flashd len={len}: a divider node fired"
            );
            for (name, st) in &summary.channel_stats {
                assert!(
                    st.peak_occupancy_elems <= 2,
                    "flashd len={len}: channel '{name}' peaked at {}",
                    st.peak_occupancy_elems
                );
            }
        }
    }

    #[test]
    fn inferred_step_depths_match_the_causal_bound() {
        let w = Workload::random(16, 4, 0xDEC3);
        for len in [1usize, 4, 16] {
            let p = w.prefix(len);
            let buffered = build_step(
                DecodeKind::Buffered,
                &p.q[len - 1],
                &p.k,
                &p.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            let long_max = buffered
                .engine
                .depth_report()
                .iter()
                .filter(|c| c.is_long)
                .map(|c| c.inferred)
                .max();
            assert_eq!(
                long_max,
                Some(step_long_fifo_bound(DecodeKind::Buffered, len)),
                "buffered len={len}"
            );

            let memfree = build_step(
                DecodeKind::MemoryFree,
                &p.q[len - 1],
                &p.k,
                &p.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            for c in memfree.engine.depth_report() {
                assert!(!c.is_long, "memfree len={len}: '{}'", c.name);
                assert_eq!(c.capacity, Capacity::Bounded(2), "len={len}: '{}'", c.name);
            }
        }
    }

    #[test]
    fn memfree_step_memory_is_constant_in_cache_length() {
        for len in [4usize, 16, 64] {
            let w = Workload::random(len, 4, 0xDEC4);
            let mut built = build_step(
                DecodeKind::MemoryFree,
                &w.q[len - 1],
                &w.k,
                &w.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            let (_, summary) = built.run().unwrap();
            for (name, st) in &summary.channel_stats {
                assert!(
                    st.peak_occupancy_elems <= 2,
                    "len={len}: channel '{name}' peaked at {}",
                    st.peak_occupancy_elems
                );
            }
        }
    }

    #[test]
    fn variant_decode_builds_the_last_chain_row() {
        let w = Workload::random(9, 4, 0xDEC5);
        let mut built = Variant::Decode
            .build(&w, &FifoPlan::paper(w.n))
            .unwrap();
        let (got, _) = built.run().unwrap();
        assert_eq!(got.len(), 1);
        let chain = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        let last: Matrix = vec![chain[w.n - 1].clone()];
        assert_close(&got, &last, 1e-6, "Variant::Decode vs chain last row");
    }

    #[test]
    fn session_validates_shapes_and_counts_steps() {
        let mut s = DecodeSession::new(DecodeKind::MemoryFree, 4);
        assert!(s.is_empty());
        let out = s
            .step(vec![0.1; 4], vec![0.2; 4], vec![0.3; 4])
            .unwrap();
        assert_eq!(out.step, 0);
        assert_eq!(out.row.len(), 4);
        let out = s
            .step(vec![0.4; 4], vec![0.5; 4], vec![0.6; 4])
            .unwrap();
        assert_eq!(out.step, 1);
        assert_eq!(s.len(), 2);
        assert_eq!(s.outputs().len(), 2);
        let err = s.step(vec![0.0; 3], vec![0.0; 4], vec![0.0; 4]);
        assert!(matches!(err, Err(Error::Graph(msg)) if msg.contains("dim 3")));
        // The failed step must not have touched the cache.
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn failed_step_leaves_the_session_cache_untouched() {
        // Under a depth-2 explicit plan the buffered step deadlocks as
        // soon as the cache outgrows the bypass (len = 3 > 2): the
        // broadcast can no longer land the last exponential before the
        // row sum completes. The error must not advance the cache — a
        // retry after the failure sees the pre-step state, not a
        // double-cached token.
        let mut s = DecodeSession::with_policy(
            DecodeKind::Buffered,
            4,
            DepthPolicy::Explicit(FifoPlan::with_long_depth(2)),
        );
        s.step(vec![0.1; 4], vec![0.2; 4], vec![0.3; 4]).unwrap();
        s.step(vec![0.4; 4], vec![0.5; 4], vec![0.6; 4]).unwrap();
        assert_eq!(s.len(), 2);
        let err = s.step(vec![0.7; 4], vec![0.8; 4], vec![0.9; 4]);
        assert!(err.is_err(), "undersized bypass must deadlock at len 3");
        assert_eq!(s.len(), 2, "failed step must not grow the cache");
        assert_eq!(s.outputs().len(), 2, "no phantom output row");
    }

    fn small_pool(block_size: usize, num_blocks: usize) -> BlockPool {
        BlockPool::new(crate::runtime::kvcache::KvCacheConfig {
            block_size,
            num_blocks,
        })
        .unwrap()
    }

    #[test]
    fn paged_session_is_bit_identical_to_contiguous() {
        let w = Workload::random(9, 4, 0x9A6E01);
        let mut pool = small_pool(2, 8);
        let mut paged = PagedDecodeSession::new(DecodeKind::MemoryFree, w.d);
        let mut contiguous = DecodeSession::new(DecodeKind::MemoryFree, w.d);
        for t in 0..w.n {
            paged
                .step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
            contiguous
                .step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        assert_eq!(
            paged.outputs(),
            contiguous.outputs(),
            "paged transcript ≡ contiguous transcript bitwise"
        );
        assert_eq!(paged.table().num_blocks(), 5, "9 rows / 2 per block");
        let outs = paged.close(&mut pool);
        assert_eq!(outs.len(), 9);
        assert_eq!(pool.used_blocks(), 0, "close releases every block");
    }

    #[test]
    fn paged_step_keeps_o1_memory_and_depths() {
        // The O(1)-per-step claim survives paging: the step graph built
        // from a block-table gather has the same depth-2-everywhere
        // report and ≤ 2-element runtime peaks as the contiguous build.
        let w = Workload::random(16, 4, 0x9A6E02);
        let mut pool = small_pool(4, 8);
        let mut s = PagedDecodeSession::new(DecodeKind::MemoryFree, w.d);
        for t in 0..w.n - 1 {
            s.step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        s.stage(&mut pool, &w.q[w.n - 1], &w.k[w.n - 1], &w.v[w.n - 1])
            .unwrap();
        let view = pool.view(s.table());
        let mut built = build_step_rows(
            DecodeKind::MemoryFree,
            &w.q[w.n - 1],
            &view.keys,
            &view.values,
            DepthPolicy::Inferred,
        )
        .unwrap();
        for c in built.engine.depth_report() {
            assert!(!c.is_long, "paged step channel '{}' is long", c.name);
            assert_eq!(c.capacity, Capacity::Bounded(2), "'{}'", c.name);
        }
        let (_, summary) = built.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "paged step channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn paged_session_survives_preempt_restore_bit_exactly() {
        let w = Workload::random(6, 4, 0x9A6E03);
        let mut pool = small_pool(2, 8);
        let mut paged = PagedDecodeSession::new(DecodeKind::MemoryFree, w.d);
        for t in 0..3 {
            paged
                .step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        paged.preempt(&mut pool);
        assert!(paged.is_preempted());
        assert_eq!(paged.len(), 3, "len visible while swapped out");
        assert_eq!(pool.used_blocks(), 0, "preempt freed the blocks");
        // The next step restores transparently.
        for t in 3..w.n {
            paged
                .step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        assert!(!paged.is_preempted());
        let baseline = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        assert_eq!(
            paged.outputs(),
            &baseline,
            "preempt/restore cycle must not perturb a bit"
        );
        paged.close(&mut pool);
    }

    #[test]
    fn forked_paged_sessions_share_prefix_and_diverge() {
        let w = Workload::random(8, 4, 0x9A6E04);
        let m = 4; // shared prefix rows (= 2 full blocks at size 2)
        let mut pool = small_pool(2, 16);
        let mut parent = PagedDecodeSession::new(DecodeKind::MemoryFree, w.d);
        for t in 0..m {
            parent
                .step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        let mut child = parent.fork(&mut pool).unwrap();
        assert_eq!(child.len(), m, "child sees the shared prefix");
        assert!(child.outputs().is_empty(), "child transcript starts empty");
        assert_eq!(pool.shared_blocks(), 2, "prefix blocks shared, not copied");
        // Child continues with the workload's suffix; a contiguous
        // session over the whole workload is the oracle for its rows.
        for t in m..w.n {
            child
                .step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        let baseline = decode_workload(DecodeKind::MemoryFree, &w).unwrap();
        assert_eq!(
            child.outputs().as_slice(),
            &baseline[m..],
            "forked continuation ≡ contiguous suffix bitwise"
        );
        // Parent is untouched by the child's appends.
        assert_eq!(parent.len(), m);
        child.close(&mut pool);
        parent.close(&mut pool);
        assert_eq!(pool.used_blocks(), 0);
    }

    #[test]
    fn paged_pool_exhaustion_defers_and_leaves_session_intact() {
        let w = Workload::random(6, 4, 0x9A6E05);
        let mut pool = small_pool(1, 2);
        let mut s = PagedDecodeSession::new(DecodeKind::MemoryFree, w.d);
        for t in 0..2 {
            s.step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        let err = s.step(
            &mut pool,
            w.q[2].clone(),
            w.k[2].clone(),
            w.v[2].clone(),
        );
        assert!(
            matches!(err, Err(Error::AdmissionDeferred(_))),
            "full pool defers, it does not hard-fail"
        );
        assert_eq!(s.len(), 2, "deferred step left the cache unchanged");
        assert_eq!(s.outputs().len(), 2, "no phantom output row");
        s.close(&mut pool);
    }

    #[test]
    fn windowed_session_matches_the_windowed_references() {
        let w = Workload::random(12, 4, 0xDEC6);
        let mask = Mask::window(5);
        let mut s = DecodeSession::new_windowed(DecodeKind::MemoryFree, w.d, 5);
        assert_eq!(s.window(), Some(5));
        for t in 0..w.n {
            let out = s
                .step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
            assert_eq!(out.step, t, "step index is the logical position");
        }
        // Same f32 operations in the same span order as the oracle.
        assert_close(
            s.outputs(),
            &sdpa_online_f32_masked(&w, &mask),
            1e-6,
            "windowed chain vs online window reference",
        );
        assert_close(
            s.outputs(),
            &sdpa_f64_masked(&w, &mask),
            1e-4,
            "windowed chain vs f64 window reference",
        );
    }

    #[test]
    fn windowed_paged_and_contiguous_sessions_are_bit_identical() {
        let w = Workload::random(16, 4, 0xDEC7);
        for kind in DecodeKind::ALL {
            let mut pool = small_pool(2, 8);
            let mut paged = PagedDecodeSession::new_windowed(kind, w.d, 3);
            let mut contiguous = DecodeSession::new_windowed(kind, w.d, 3);
            assert_eq!(paged.window(), Some(3));
            for t in 0..w.n {
                paged
                    .step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                    .unwrap();
                contiguous
                    .step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                    .unwrap();
                assert!(
                    paged.table().num_blocks() <= 2,
                    "{kind}: windowed footprint capped at ⌈3/2⌉ blocks"
                );
            }
            assert_eq!(
                paged.outputs(),
                contiguous.outputs(),
                "{kind}: windowed paged ≡ windowed contiguous bitwise"
            );
            paged.close(&mut pool);
            assert_eq!(pool.used_blocks(), 0);
        }
    }

    #[test]
    fn windowed_step_bound_is_min_len_window_plus_2() {
        // A windowed step streams min(len, W) rows, so the buffered
        // bypass bound compresses to min(len, W) + 2 and stays flat
        // once the window fills — the FIFO-depth face of O(W) serving.
        let w = Workload::random(12, 4, 0xDEC8);
        let win = 4;
        let mut s = DecodeSession::new_windowed(DecodeKind::Buffered, w.d, win);
        for t in 0..w.n {
            let out = s
                .step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
            let long_max = out
                .summary
                .depths
                .iter()
                .filter(|c| c.is_long)
                .map(|c| c.inferred)
                .max();
            let expect = step_long_fifo_bound(DecodeKind::Buffered, (t + 1).min(win));
            assert_eq!(long_max, Some(expect), "step {t}");
        }
        // The memory-free mapping needs no bypass at any window.
        let mut s = DecodeSession::new_windowed(DecodeKind::MemoryFree, w.d, win);
        for t in 0..w.n {
            let out = s
                .step(w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
            for c in &out.summary.depths {
                assert!(!c.is_long, "step {t}: '{}'", c.name);
            }
        }
    }

    #[test]
    fn windowed_paged_session_survives_preempt_restore_bit_exactly() {
        // Preempt a windowed session after its ring has wrapped; the
        // restored ring must continue exactly like an unpreempted twin.
        let w = Workload::random(14, 4, 0xDEC9);
        let mut pool = small_pool(2, 16);
        let mut a = PagedDecodeSession::new_windowed(DecodeKind::MemoryFree, w.d, 3);
        let mut b = PagedDecodeSession::new_windowed(DecodeKind::MemoryFree, w.d, 3);
        for t in 0..10 {
            a.step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
            b.step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        a.preempt(&mut pool);
        assert!(a.is_preempted());
        assert_eq!(a.len(), 10, "logical len visible while swapped out");
        for t in 10..w.n {
            a.step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
            b.step(&mut pool, w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
                .unwrap();
        }
        assert_eq!(
            a.outputs(),
            b.outputs(),
            "preempt/restore of a wrapped ring must not perturb a bit"
        );
        a.close(&mut pool);
        b.close(&mut pool);
        assert_eq!(pool.used_blocks(), 0);
    }

    fn run_segment(
        q: &[f32],
        keys: &[&[f32]],
        values: &[&[f32]],
        carry: &SoftmaxCarry,
        finalize: bool,
    ) -> Vec<f32> {
        let mut g = crate::sim::GraphBuilder::new();
        let mut sc = g.root();
        let h = build_chunk_segment_into(&mut sc, q, keys, values, carry, finalize).unwrap();
        let mut engine = g.compile(DepthPolicy::Inferred).unwrap();
        engine
            .run(super::super::cycle_budget(keys.len()))
            .unwrap();
        let mut rows = h.rows();
        assert_eq!(rows.len(), 1, "a chunk segment emits exactly one row");
        rows.pop().unwrap()
    }

    #[test]
    fn chunked_segments_reproduce_the_unsplit_step_bitwise() {
        // The heart of chunked prefill: splitting a row's key scan at
        // any point and carrying (m, r, ℓ⃗) across the split must give
        // the bitwise-identical output row to the unsplit step.
        let w = Workload::random(10, 4, 0xC41C);
        let mut solo = build_step(
            DecodeKind::MemoryFree,
            &w.q[9],
            &w.k,
            &w.v,
            DepthPolicy::Inferred,
        )
        .unwrap();
        let (solo_rows, _) = solo.run().unwrap();
        let keys: Vec<&[f32]> = w.k.iter().map(Vec::as_slice).collect();
        let values: Vec<&[f32]> = w.v.iter().map(Vec::as_slice).collect();
        for split in [1usize, 3, 4, 9] {
            let packed = run_segment(
                &w.q[9],
                &keys[..split],
                &values[..split],
                &SoftmaxCarry::fresh(w.d),
                false,
            );
            assert_eq!(packed.len(), w.d + 2, "carry row is [m, r, ℓ⃗]");
            let carry = SoftmaxCarry::unpack(&packed).unwrap();
            let row = run_segment(&w.q[9], &keys[split..], &values[split..], &carry, true);
            assert_eq!(row, solo_rows[0], "split at {split} must not move a bit");
        }
        // Three-way split through a carry chain.
        let c1 = SoftmaxCarry::unpack(&run_segment(
            &w.q[9],
            &keys[..2],
            &values[..2],
            &SoftmaxCarry::fresh(w.d),
            false,
        ))
        .unwrap();
        let c2 = SoftmaxCarry::unpack(&run_segment(&w.q[9], &keys[2..7], &values[2..7], &c1, false))
            .unwrap();
        let row = run_segment(&w.q[9], &keys[7..], &values[7..], &c2, true);
        assert_eq!(row, solo_rows[0], "three-segment chain must not move a bit");
    }

    #[test]
    fn fresh_full_span_segment_is_the_ordinary_step() {
        // finalize + fresh carry + full key span builds the memory-free
        // step graph: bitwise the same row.
        let w = Workload::random(7, 4, 0xC41D);
        let keys: Vec<&[f32]> = w.k.iter().map(Vec::as_slice).collect();
        let values: Vec<&[f32]> = w.v.iter().map(Vec::as_slice).collect();
        let row = run_segment(&w.q[6], &keys, &values, &SoftmaxCarry::fresh(w.d), true);
        let mut solo = build_step(
            DecodeKind::MemoryFree,
            &w.q[6],
            &w.k,
            &w.v,
            DepthPolicy::Inferred,
        )
        .unwrap();
        let (solo_rows, _) = solo.run().unwrap();
        assert_eq!(row, solo_rows[0]);
    }

    #[test]
    fn carry_pack_unpack_roundtrips() {
        let c = SoftmaxCarry {
            m: 1.25,
            r: 0.5,
            acc: vec![0.1, -0.2, 0.3],
        };
        assert_eq!(SoftmaxCarry::unpack(&c.pack()).unwrap(), c);
        assert!(SoftmaxCarry::fresh(3).is_fresh());
        assert!(!c.is_fresh());
        assert!(SoftmaxCarry::unpack(&[1.0, 2.0]).is_err());
    }

    #[test]
    fn chunk_segment_rejects_bad_shapes() {
        let q = [1.0f32, 2.0];
        let k: Vec<&[f32]> = vec![&[1.0, 2.0]];
        let v: Vec<&[f32]> = vec![&[1.0, 2.0]];
        let mut g = crate::sim::GraphBuilder::new();
        let mut sc = g.root();
        // Empty span.
        assert!(
            build_chunk_segment_into(&mut sc, &q, &[], &[], &SoftmaxCarry::fresh(2), true).is_err()
        );
        // Carry of the wrong width.
        assert!(build_chunk_segment_into(&mut sc, &q, &k, &v, &SoftmaxCarry::fresh(3), true)
            .is_err());
        // Ragged values.
        let bad_v: Vec<&[f32]> = vec![&[1.0]];
        assert!(
            build_chunk_segment_into(&mut sc, &q, &k, &bad_v, &SoftmaxCarry::fresh(2), false)
                .is_err()
        );
    }

    #[test]
    fn chunk_segments_keep_o1_memory() {
        // The paper's O(1)-per-pipeline claim survives chunking: every
        // FIFO of a mid-row segment peaks at ≤ 2 elements.
        let w = Workload::random(32, 4, 0xC41E);
        let keys: Vec<&[f32]> = w.k.iter().map(Vec::as_slice).collect();
        let values: Vec<&[f32]> = w.v.iter().map(Vec::as_slice).collect();
        let mut g = crate::sim::GraphBuilder::new();
        let mut sc = g.root();
        let h = build_chunk_segment_into(
            &mut sc,
            &w.q[31],
            &keys[..20],
            &values[..20],
            &SoftmaxCarry::fresh(w.d),
            false,
        )
        .unwrap();
        let mut engine = g.compile(DepthPolicy::Inferred).unwrap();
        let summary = engine.run(super::super::cycle_budget(20)).unwrap();
        assert_eq!(h.rows().len(), 1);
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "chunk channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn build_step_rejects_empty_and_ragged_caches() {
        let empty = build_step(DecodeKind::MemoryFree, &[1.0], &[], &[], DepthPolicy::Inferred);
        assert!(empty.is_err());
        let err = build_step(
            DecodeKind::MemoryFree,
            &[1.0, 2.0],
            &[vec![1.0, 2.0]],
            &[vec![1.0]],
            DepthPolicy::Inferred,
        );
        assert!(err.is_err());
    }
}
