//! The paper's four attention dataflow graphs.
//!
//! | Variant | Paper figure | Long FIFOs | Intermediate memory |
//! |---|---|---|---|
//! | [`Variant::Naive`] | Fig. 2 | `e_bypass` (depth N+2) | O(N) |
//! | [`Variant::Scaled`] | Fig. 3(a) | `s_bypass`, `e_bypass` | 2·O(N) |
//! | [`Variant::Reordered`] | Fig. 3(b) | `s_bypass` | O(N) |
//! | [`Variant::MemoryFree`] | Fig. 3(c) | none | O(1) |
//!
//! Every graph streams Q rows against resident K/V operands, produces
//! one output row per N cycles at steady state (II = 1 per element), and
//! is numerically validated against [`reference`].

pub mod memfree;
pub mod multihead;
pub mod naive;
pub mod reference;
pub mod reordered;
pub mod scaled;
pub mod workload;

use crate::sim::nodes::SinkHandle;
use crate::sim::{Capacity, ChannelId, Elem, Engine, GraphBuilder, RunSummary};
use crate::{Error, Result};
use reference::Matrix;
use workload::{dot, Workload};

/// Which attention implementation to map onto the abstract hardware.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// §3 / Figure 2: softmax without max subtraction, row-sum division.
    Naive,
    /// Figure 3(a): softmax with scaling (row max), division in place.
    Scaled,
    /// Figure 3(b): division reordered past the PV contraction.
    Reordered,
    /// Figure 3(c): running max + running sums; the memory-free version.
    MemoryFree,
}

impl Variant {
    /// All variants, in paper order.
    pub const ALL: [Variant; 4] = [
        Variant::Naive,
        Variant::Scaled,
        Variant::Reordered,
        Variant::MemoryFree,
    ];

    /// Stable lowercase name (CLI + reports).
    pub fn name(self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Scaled => "scaled",
            Variant::Reordered => "reordered",
            Variant::MemoryFree => "memfree",
        }
    }

    /// Paper figure this variant reproduces.
    pub fn figure(self) -> &'static str {
        match self {
            Variant::Naive => "Fig. 2",
            Variant::Scaled => "Fig. 3(a)",
            Variant::Reordered => "Fig. 3(b)",
            Variant::MemoryFree => "Fig. 3(c)",
        }
    }

    /// Names of this variant's long (latency-balancing) FIFOs.
    pub fn long_fifos(self) -> &'static [&'static str] {
        match self {
            Variant::Naive => &["e_bypass"],
            Variant::Scaled => &["s_bypass", "e_bypass"],
            Variant::Reordered => &["s_bypass"],
            Variant::MemoryFree => &[],
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Variant> {
        Variant::ALL
            .into_iter()
            .find(|v| v.name() == s)
            .ok_or_else(|| {
                Error::Usage(format!(
                    "unknown variant '{s}' (expected one of: naive, scaled, reordered, memfree)"
                ))
            })
    }

    /// Build this variant's graph over `w` with the given FIFO plan.
    pub fn build(self, w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
        match self {
            Variant::Naive => naive::build(w, plan),
            Variant::Scaled => scaled::build(w, plan),
            Variant::Reordered => reordered::build(w, plan),
            Variant::MemoryFree => memfree::build(w, plan),
        }
    }

    /// The reference implementation this variant must agree with
    /// numerically (structure-matched, not just value-matched).
    pub fn reference(self, w: &Workload) -> Matrix {
        match self {
            Variant::Naive => reference::sdpa_f32_unscaled(w),
            Variant::Scaled | Variant::Reordered => reference::sdpa_f32_scaled(w),
            Variant::MemoryFree => reference::sdpa_online_f32(w),
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// FIFO depth configuration for one build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FifoPlan {
    /// Depth of every ordinary FIFO (the paper uses 2).
    pub short: Capacity,
    /// Depth of the designated long FIFO(s) (the paper uses N+2).
    pub long: Capacity,
}

impl FifoPlan {
    /// The paper's configuration: short = 2, long = N+2.
    pub fn paper(n: usize) -> Self {
        FifoPlan {
            short: Capacity::Bounded(2),
            long: Capacity::Bounded(n + 2),
        }
    }

    /// The paper's peak-throughput baseline: everything unbounded.
    pub fn unbounded() -> Self {
        FifoPlan {
            short: Capacity::Unbounded,
            long: Capacity::Unbounded,
        }
    }

    /// Short FIFOs at 2, long FIFOs at an explicit depth (for sweeps).
    pub fn with_long_depth(depth: usize) -> Self {
        FifoPlan {
            short: Capacity::Bounded(2),
            long: Capacity::Bounded(depth),
        }
    }
}

/// A built attention graph ready to simulate.
pub struct BuiltAttention {
    /// The underlying engine (exposed for capacity sweeps / re-runs).
    pub engine: Engine,
    /// Output rows arrive here.
    pub out: SinkHandle,
    /// Sequence length.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
}

impl BuiltAttention {
    /// Generous default cycle budget for an N×N workload.
    pub fn default_budget(&self) -> u64 {
        let n = self.n as u64;
        10 * n * n + 20 * n + 500
    }

    /// Run to completion; return the output matrix and run summary.
    pub fn run(&mut self) -> Result<(Matrix, RunSummary)> {
        let budget = self.default_budget();
        let summary = self.engine.run(budget)?;
        Ok((self.out.rows(), summary))
    }

    /// Run, treating deadlock as data (depth sweeps).
    pub fn run_outcome(&mut self) -> RunSummary {
        let budget = self.default_budget();
        self.engine.run_outcome(budget)
    }
}

// ---------------------------------------------------------------------
// Shared sub-graphs
// ---------------------------------------------------------------------

/// Build the score front-end shared by all variants:
///
/// ```text
/// Source(Q rows) → Repeat(N) ─┐
///                             Zip(dot · 1/√d) → s_ij stream (N² scalars)
/// Source(Kᵀ cols, cyclic) ────┘
/// ```
///
/// Returns the `s` channel carrying row-major scores.
pub(crate) fn build_score_frontend(
    g: &mut GraphBuilder,
    w: &Workload,
    plan: &FifoPlan,
) -> Result<ChannelId> {
    let n = w.n;
    let total = (n * n) as u64;
    let q_rows = g.channel("q_rows", plan.short)?;
    let q_rep = g.channel("q_rep", plan.short)?;
    let k_cols = g.channel("k_cols", plan.short)?;
    let s = g.channel("s", plan.short)?;

    let q: Vec<Elem> = w.q.iter().map(|r| Elem::vector(r)).collect();
    g.source_vec("src_q", q_rows, q)?;
    g.repeat("rep_q", q_rows, q_rep, n)?;

    // K is a resident operand: a memory unit + address generator replays
    // its rows (columns of Kᵀ) once per query row.
    let k: Vec<Elem> = w.k.iter().map(|r| Elem::vector(r)).collect();
    g.source_gen("src_k", k_cols, total, move |i| {
        k[(i % n as u64) as usize].clone()
    })?;

    let scale = w.scale();
    g.zip("qk_dot", &[q_rep, k_cols], s, move |xs| {
        Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
    })?;
    Ok(s)
}

/// Add a cyclic V-row source (`len = N²`, row `j = i mod N`).
pub(crate) fn build_v_source(
    g: &mut GraphBuilder,
    w: &Workload,
    plan: &FifoPlan,
    name: &str,
) -> Result<ChannelId> {
    let n = w.n;
    let total = (n * n) as u64;
    let v_cols = g.channel(name, plan.short)?;
    let v: Vec<Elem> = w.v.iter().map(|r| Elem::vector(r)).collect();
    g.source_gen("src_v", v_cols, total, move |i| {
        v[(i % n as u64) as usize].clone()
    })?;
    Ok(v_cols)
}

/// Build the probability-weighted-value tail shared by Fig. 2 / Fig. 3(a):
///
/// ```text
/// p_ij ─┐
///       Zip(p · v⃗) → MemReduce(N, 0⃗, +) → o⃗_i → Sink
/// v⃗_j ──┘
/// ```
pub(crate) fn build_pv_tail(
    g: &mut GraphBuilder,
    w: &Workload,
    plan: &FifoPlan,
    p: ChannelId,
) -> Result<SinkHandle> {
    let n = w.n;
    let d = w.d;
    let v_cols = build_v_source(g, w, plan, "v_cols")?;
    let pv = g.channel("pv", plan.short)?;
    let o = g.channel("o", plan.short)?;
    g.zip("pv_mul", &[p, v_cols], pv, |xs| {
        let p = xs[0].scalar();
        Elem::from(xs[1].as_vector().iter().map(|v| p * v).collect::<Vec<_>>())
    })?;
    g.mem_reduce("pv_acc", pv, o, n, vec![0.0; d], |acc, x| {
        acc.iter().zip(x.as_vector()).map(|(a, b)| a + b).collect()
    })?;
    g.sink("sink_o", o, Some(n as u64))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
            assert_eq!(format!("{v}"), v.name());
        }
        assert!(Variant::parse("bogus").is_err());
    }

    #[test]
    fn long_fifo_counts_match_paper() {
        assert_eq!(Variant::Naive.long_fifos().len(), 1);
        assert_eq!(Variant::Scaled.long_fifos().len(), 2);
        assert_eq!(Variant::Reordered.long_fifos().len(), 1);
        assert_eq!(Variant::MemoryFree.long_fifos().len(), 0);
    }

    #[test]
    fn paper_plan_depths() {
        let p = FifoPlan::paper(64);
        assert_eq!(p.short, Capacity::Bounded(2));
        assert_eq!(p.long, Capacity::Bounded(66));
    }

    #[test]
    fn score_frontend_streams_row_major_scores() {
        let w = Workload::random(4, 3, 21);
        let mut g = GraphBuilder::new();
        let plan = FifoPlan::paper(w.n);
        let s = build_score_frontend(&mut g, &w, &plan).unwrap();
        let h = g.sink("sink", s, Some(16)).unwrap();
        let mut e = g.build().unwrap();
        e.run(10_000).unwrap();
        let got = h.scalars();
        assert_eq!(got.len(), 16);
        for i in 0..4 {
            for j in 0..4 {
                assert!(
                    (got[i * 4 + j] - w.score(i, j)).abs() < 1e-6,
                    "score ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn frontend_full_throughput_at_depth_2() {
        let w = Workload::random(16, 4, 2);
        let mut g = GraphBuilder::new();
        let plan = FifoPlan::paper(w.n);
        let s = build_score_frontend(&mut g, &w, &plan).unwrap();
        let h = g.sink("sink", s, Some(256)).unwrap();
        let mut e = g.build().unwrap();
        e.run(100_000).unwrap();
        assert_eq!(h.arrival_gaps(128), Some((1, 1)), "II=1 steady state");
    }
}
