//! The paper's four attention dataflow graphs, their causal (masked)
//! twins, and the autoregressive decode mapping.
//!
//! | Variant | Paper figure | Long FIFOs | Intermediate memory |
//! |---|---|---|---|
//! | [`Variant::Naive`] | Fig. 2 | `e_bypass` (depth N+2) | O(N) |
//! | [`Variant::Scaled`] | Fig. 3(a) | `s_bypass`, `e_bypass` | 2·O(N) |
//! | [`Variant::Reordered`] | Fig. 3(b) | `s_bypass` | O(N) |
//! | [`Variant::MemoryFree`] | Fig. 3(c) | none | O(1) |
//! | [`Variant::CausalNaive`] … [`Variant::CausalMemoryFree`] | same + causal mask | same as base | same as base |
//! | [`Variant::Decode`] | decode step (1×N) | none | O(1) per step |
//! | [`Variant::FlashD`] | FLASH-D (division-free) | none | O(1), no divider node |
//!
//! Every prefill graph streams Q rows against resident K/V operands,
//! produces one output row per N cycles at steady state (II = 1 per
//! element), and is numerically validated against [`reference`]. The
//! causal variants mask scores *in the stream* (see [`causal`]) — the
//! topology, and therefore every FIFO bound, is unchanged. The decode
//! variant builds one autoregressive step (see [`decode`]): a single
//! query row against the full K/V cache, O(1) intermediate memory.
//!
//! ## Construction model
//!
//! The builders use the `sim` **port API**: node helpers return typed
//! [`Port`]s, channels appear implicitly, and
//! [`GraphBuilder::compile`](crate::sim::GraphBuilder::compile) sizes
//! every FIFO under a [`DepthPolicy`]. The default
//! [`DepthPolicy::Inferred`] derives the long-FIFO depths (the paper's
//! N+2) from the graph structure, so a builder like [`memfree::build`]
//! mentions **no channel names and no depths**; the `FifoPlan`-taking
//! entry points remain for depth sweeps and ablations and are exactly
//! `DepthPolicy::Explicit(plan)`. Multi-head graphs compose by
//! instantiating one head per [`Scope`](crate::sim::Scope) — see
//! [`multihead`].

pub mod causal;
pub mod decode;
pub mod flashd;
pub mod memfree;
pub mod multihead;
pub mod naive;
pub mod reference;
pub mod reordered;
pub mod scaled;
pub mod workload;

use crate::sim::nodes::SinkHandle;
use crate::sim::{Elem, Engine, Port, RunSummary, Scope};
use crate::{Error, Result};
use reference::Matrix;
use workload::{dot, Workload};

pub use crate::sim::{DepthPolicy, FifoPlan};
pub use workload::Mask;

/// Which attention implementation to map onto the abstract hardware.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// §3 / Figure 2: softmax without max subtraction, row-sum division.
    Naive,
    /// Figure 3(a): softmax with scaling (row max), division in place.
    Scaled,
    /// Figure 3(b): division reordered past the PV contraction.
    Reordered,
    /// Figure 3(c): running max + running sums; the memory-free version.
    MemoryFree,
    /// Figure 2 with an in-stream causal mask.
    CausalNaive,
    /// Figure 3(a) with an in-stream causal mask.
    CausalScaled,
    /// Figure 3(b) with an in-stream causal mask.
    CausalReordered,
    /// Figure 3(c) with an in-stream causal mask — causal serving at
    /// O(1) intermediate memory.
    CausalMemoryFree,
    /// One autoregressive decode step (the serving steady state): the
    /// last query row streamed against the full K/V cache through the
    /// memory-free recurrence. Sessions chain these — see [`decode`].
    Decode,
    /// FLASH-D (PAPERS.md): the memory-free recurrence with the softmax
    /// division hidden inside the exponential — a running log-sum-exp
    /// emits already-normalized weights `w = e^{s−t}` and the output is
    /// an exact EMA `o⃗ ← o⃗ + w·(v⃗ − o⃗)`. No divider node anywhere in
    /// the graph; see [`flashd`].
    FlashD,
}

impl Variant {
    /// All variants: paper order first, then the causal/decode family,
    /// then the division-free FLASH-D extension.
    pub const ALL: [Variant; 10] = [
        Variant::Naive,
        Variant::Scaled,
        Variant::Reordered,
        Variant::MemoryFree,
        Variant::CausalNaive,
        Variant::CausalScaled,
        Variant::CausalReordered,
        Variant::CausalMemoryFree,
        Variant::Decode,
        Variant::FlashD,
    ];

    /// The paper's four prefill variants (Figures 2, 3a–c) — the set
    /// the figure-replication experiments sweep.
    pub const PAPER: [Variant; 4] = [
        Variant::Naive,
        Variant::Scaled,
        Variant::Reordered,
        Variant::MemoryFree,
    ];

    /// Stable lowercase name (CLI + reports).
    pub fn name(self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Scaled => "scaled",
            Variant::Reordered => "reordered",
            Variant::MemoryFree => "memfree",
            Variant::CausalNaive => "causal-naive",
            Variant::CausalScaled => "causal-scaled",
            Variant::CausalReordered => "causal-reordered",
            Variant::CausalMemoryFree => "causal-memfree",
            Variant::Decode => "decode",
            Variant::FlashD => "flashd",
        }
    }

    /// Paper figure this variant reproduces (or extends).
    pub fn figure(self) -> &'static str {
        match self {
            Variant::Naive => "Fig. 2",
            Variant::Scaled => "Fig. 3(a)",
            Variant::Reordered => "Fig. 3(b)",
            Variant::MemoryFree => "Fig. 3(c)",
            Variant::CausalNaive => "Fig. 2 + causal",
            Variant::CausalScaled => "Fig. 3(a) + causal",
            Variant::CausalReordered => "Fig. 3(b) + causal",
            Variant::CausalMemoryFree => "Fig. 3(c) + causal",
            Variant::Decode => "decode step (1×N)",
            Variant::FlashD => "FLASH-D (division-free)",
        }
    }

    /// The underlying prefill algorithm: causal variants map to their
    /// unmasked base, the decode step to the memory-free recurrence.
    pub fn base(self) -> Variant {
        match self {
            Variant::CausalNaive => Variant::Naive,
            Variant::CausalScaled => Variant::Scaled,
            Variant::CausalReordered => Variant::Reordered,
            Variant::CausalMemoryFree | Variant::Decode => Variant::MemoryFree,
            v => v,
        }
    }

    /// Whether this is a masked (causal) prefill variant.
    pub fn is_causal(self) -> bool {
        matches!(
            self,
            Variant::CausalNaive
                | Variant::CausalScaled
                | Variant::CausalReordered
                | Variant::CausalMemoryFree
        )
    }

    /// Whether this is the decode-step variant.
    pub fn is_decode(self) -> bool {
        matches!(self, Variant::Decode)
    }

    /// The score mask this variant applies.
    pub fn mask(self) -> Mask {
        if self.is_causal() || self.is_decode() {
            Mask::Causal
        } else {
            Mask::Full
        }
    }

    /// Names of this variant's long (latency-balancing) FIFOs. The
    /// compile-time depth analysis flags exactly these channels
    /// (`ChannelDepth::is_long`) — asserted by the integration tests.
    /// In-stream masking does not change the stream timing, so the
    /// causal variants share their base's long FIFOs (and N+2 bound).
    pub fn long_fifos(self) -> &'static [&'static str] {
        match self {
            Variant::Naive | Variant::CausalNaive => &["e_bypass"],
            Variant::Scaled | Variant::CausalScaled => &["s_bypass", "e_bypass"],
            Variant::Reordered | Variant::CausalReordered => &["s_bypass"],
            Variant::MemoryFree
            | Variant::CausalMemoryFree
            | Variant::Decode
            | Variant::FlashD => &[],
        }
    }

    /// `name|name|…` over [`Variant::ALL`] — usage strings derive from
    /// this so the CLI can never fall out of sync with the enum.
    pub fn usage_list() -> String {
        Variant::ALL
            .iter()
            .map(|v| v.name())
            .collect::<Vec<_>>()
            .join("|")
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Result<Variant> {
        Variant::ALL
            .into_iter()
            .find(|v| v.name() == s)
            .ok_or_else(|| {
                let names: Vec<&str> = Variant::ALL.iter().map(|v| v.name()).collect();
                Error::Usage(format!(
                    "unknown variant '{s}' (expected one of: {})",
                    names.join(", ")
                ))
            })
    }

    /// Build this variant's graph over `w` with the given FIFO plan —
    /// shorthand for `build_with_policy(w, DepthPolicy::Explicit(*plan))`.
    pub fn build(self, w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
        self.build_with_policy(w, DepthPolicy::Explicit(*plan))
    }

    /// Build with compile-time inferred FIFO depths (no hand plan).
    pub fn build_inferred(self, w: &Workload) -> Result<BuiltAttention> {
        self.build_with_policy(w, DepthPolicy::Inferred)
    }

    /// Build this variant's graph over `w` under a depth policy.
    pub fn build_with_policy(self, w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
        match self {
            Variant::Naive => naive::build_with_policy(w, policy),
            Variant::Scaled => scaled::build_with_policy(w, policy),
            Variant::Reordered => reordered::build_with_policy(w, policy),
            Variant::MemoryFree => memfree::build_with_policy(w, policy),
            Variant::CausalNaive
            | Variant::CausalScaled
            | Variant::CausalReordered
            | Variant::CausalMemoryFree => {
                causal::build_masked(self.base(), w, &Mask::Causal, policy)
            }
            Variant::Decode => decode::build_last_row(w, policy),
            Variant::FlashD => flashd::build_with_policy(w, policy),
        }
    }

    /// The reference implementation this variant must agree with
    /// numerically (structure-matched, not just value-matched).
    pub fn reference(self, w: &Workload) -> Matrix {
        match self {
            Variant::Naive => reference::sdpa_f32_unscaled(w),
            Variant::Scaled | Variant::Reordered => reference::sdpa_f32_scaled(w),
            Variant::MemoryFree => reference::sdpa_online_f32(w),
            Variant::CausalNaive => reference::sdpa_f32_unscaled_masked(w, &Mask::Causal),
            Variant::CausalScaled | Variant::CausalReordered => {
                reference::sdpa_f32_scaled_masked(w, &Mask::Causal)
            }
            Variant::CausalMemoryFree => reference::sdpa_online_f32_masked(w, &Mask::Causal),
            Variant::Decode => vec![reference::sdpa_online_f32_masked(w, &Mask::Causal)
                .pop()
                .expect("workloads have n ≥ 1")],
            Variant::FlashD => reference::sdpa_flashd_f32(w),
        }
    }

    /// The f64 accuracy oracle computing the same *function* as this
    /// variant (full attention for the prefill variants, causal
    /// attention for the masked ones, the final causal row for the
    /// decode step) — what end-to-end numeric tests compare against.
    pub fn oracle_f64(self, w: &Workload) -> Matrix {
        match self {
            Variant::Naive
            | Variant::Scaled
            | Variant::Reordered
            | Variant::MemoryFree
            | Variant::FlashD => reference::sdpa_f64(w),
            Variant::CausalNaive
            | Variant::CausalScaled
            | Variant::CausalReordered
            | Variant::CausalMemoryFree => reference::sdpa_f64_masked(w, &Mask::Causal),
            Variant::Decode => vec![reference::sdpa_f64_masked(w, &Mask::Causal)
                .pop()
                .expect("workloads have n ≥ 1")],
        }
    }
}

impl std::fmt::Display for Variant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Generous simulation cycle budget for an N×N attention workload:
/// ~10 cycles of slack per score plus fill. Shared by every runner
/// (single-head and multi-head) so the bound lives in one place.
pub fn cycle_budget(n: usize) -> u64 {
    let n = n as u64;
    10 * n * n + 20 * n + 500
}

/// A built attention graph ready to simulate.
pub struct BuiltAttention {
    /// The underlying engine (exposed for capacity sweeps / re-runs and
    /// its compile-time depth report).
    pub engine: Engine,
    /// Output rows arrive here.
    pub out: SinkHandle,
    /// Sequence length.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
}

impl BuiltAttention {
    /// Generous default cycle budget for an N×N workload.
    pub fn default_budget(&self) -> u64 {
        cycle_budget(self.n)
    }

    /// Run to completion; return the output matrix and run summary.
    pub fn run(&mut self) -> Result<(Matrix, RunSummary)> {
        let budget = self.default_budget();
        let summary = self.engine.run(budget)?;
        Ok((self.out.rows(), summary))
    }

    /// Run, treating deadlock as data (depth sweeps).
    pub fn run_outcome(&mut self) -> RunSummary {
        let budget = self.default_budget();
        self.engine.run_outcome(budget)
    }
}

// ---------------------------------------------------------------------
// Shared sub-graphs (port API)
// ---------------------------------------------------------------------

/// Build the score front-end shared by all variants:
///
/// ```text
/// Source(Q rows) → Repeat(N) ─┐
///                             Zip(dot · 1/√d) → s_ij stream (N² scalars)
/// Source(Kᵀ cols, cyclic) ────┘
/// ```
///
/// Returns the port carrying row-major scores.
pub(crate) fn score_frontend(sc: &mut Scope<'_>, w: &Workload) -> Result<Port> {
    let (q_rep, k_cols) = qk_sources(sc, w)?;
    let scale = w.scale();
    sc.zip("qk_dot", [q_rep, k_cols], move |xs| {
        Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
    })
}

/// The Q/K operand delivery shared by the masked and unmasked score
/// front-ends: Q rows repeated N times each, and K as a resident
/// operand whose rows (columns of Kᵀ) a memory unit + address
/// generator replays once per query row.
fn qk_sources(sc: &mut Scope<'_>, w: &Workload) -> Result<(Port, Port)> {
    let n = w.n;
    let total = (n * n) as u64;

    let q: Vec<Elem> = w.q.iter().map(|r| Elem::vector(r)).collect();
    let q_rows = sc.source_vec("src_q", q)?;
    let q_rep = sc.repeat("rep_q", q_rows, n)?;

    let k: Vec<Elem> = w.k.iter().map(|r| Elem::vector(r)).collect();
    let k_cols = sc.source_gen("src_k", total, move |i| k[(i % n as u64) as usize].clone())?;
    Ok((q_rep, k_cols))
}

/// [`score_frontend`] with an in-stream mask: a third, *stateless* mask
/// stream joins the q·k zip, so masked positions emit −∞ scores without
/// perturbing the stream timing — masked elements still occupy their
/// slot each cycle, which is why in-stream masking leaves every
/// long-FIFO bound unchanged (see [`causal`]). The mask rides a
/// [`Scope::source_gen`] (index-driven, no captured counter), so
/// [`Engine::reset`] replays are bit-identical — a stateful counting
/// `Map` would keep counting across resets.
pub(crate) fn score_frontend_masked(
    sc: &mut Scope<'_>,
    w: &Workload,
    mask: &Mask,
) -> Result<Port> {
    if *mask == Mask::Full {
        return score_frontend(sc, w);
    }
    let n = w.n;
    let total = (n * n) as u64;
    let (q_rep, k_cols) = qk_sources(sc, w)?;

    // The mask is a configured address pattern, not data: stream
    // element t is score (i, j) = (t / N, t mod N).
    let m = mask.clone();
    let bits = sc.source_gen("src_mask", total, move |t| {
        let i = (t / n as u64) as usize;
        let j = (t % n as u64) as usize;
        Elem::Scalar(if m.visible(i, j) { 1.0 } else { 0.0 })
    })?;

    let scale = w.scale();
    sc.zip("qk_dot", [q_rep, k_cols, bits], move |xs| {
        if xs[2].scalar() == 0.0 {
            Elem::Scalar(f32::NEG_INFINITY)
        } else {
            Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
        }
    })
}

/// Add a cyclic V-row source (`len = N²`, row `j = i mod N`).
pub(crate) fn v_source(sc: &mut Scope<'_>, w: &Workload) -> Result<Port> {
    let n = w.n;
    let total = (n * n) as u64;
    let v: Vec<Elem> = w.v.iter().map(|r| Elem::vector(r)).collect();
    sc.source_gen("src_v", total, move |i| v[(i % n as u64) as usize].clone())
}

/// Build the probability-weighted-value tail shared by Fig. 2 / Fig. 3(a):
///
/// ```text
/// p_ij ─┐
///       Zip(p · v⃗) → MemReduce(N, 0⃗, +) → o⃗_i → Sink
/// v⃗_j ──┘
/// ```
pub(crate) fn pv_tail(sc: &mut Scope<'_>, w: &Workload, p: Port) -> Result<SinkHandle> {
    let n = w.n;
    let d = w.d;
    let v_cols = v_source(sc, w)?;
    let pv = sc.zip("pv_mul", [p, v_cols], |xs| {
        let p = xs[0].scalar();
        Elem::from(xs[1].as_vector().iter().map(|v| p * v).collect::<Vec<_>>())
    })?;
    let o = sc.mem_reduce("pv_acc", pv, n, vec![0.0; d], |acc, x| {
        acc.iter().zip(x.as_vector()).map(|(a, b)| a + b).collect()
    })?;
    sc.sink("sink_o", o, Some(n as u64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Capacity, GraphBuilder};

    #[test]
    fn variant_names_roundtrip() {
        for v in Variant::ALL {
            assert_eq!(Variant::parse(v.name()).unwrap(), v);
            assert_eq!(format!("{v}"), v.name());
        }
        assert!(Variant::parse("bogus").is_err());
    }

    #[test]
    fn parse_error_lists_every_variant() {
        let err = Variant::parse("bogus").unwrap_err().to_string();
        for v in Variant::ALL {
            assert!(err.contains(v.name()), "message misses {v}: {err}");
        }
    }

    #[test]
    fn long_fifo_counts_match_paper() {
        assert_eq!(Variant::Naive.long_fifos().len(), 1);
        assert_eq!(Variant::Scaled.long_fifos().len(), 2);
        assert_eq!(Variant::Reordered.long_fifos().len(), 1);
        assert_eq!(Variant::MemoryFree.long_fifos().len(), 0);
        // Causal twins share their base's long FIFOs; decode has none.
        for v in Variant::ALL {
            if v.is_causal() {
                assert_eq!(v.long_fifos(), v.base().long_fifos(), "{v}");
            }
        }
        assert_eq!(Variant::Decode.long_fifos().len(), 0);
    }

    #[test]
    fn usage_list_names_every_variant() {
        let usage = Variant::usage_list();
        for v in Variant::ALL {
            assert!(usage.contains(v.name()), "usage list misses {v}: {usage}");
        }
        assert!(usage.contains("causal-memfree") && usage.contains("decode"));
    }

    #[test]
    fn base_and_mask_classification() {
        assert_eq!(Variant::CausalNaive.base(), Variant::Naive);
        assert_eq!(Variant::CausalMemoryFree.base(), Variant::MemoryFree);
        assert_eq!(Variant::Decode.base(), Variant::MemoryFree);
        assert_eq!(Variant::Reordered.base(), Variant::Reordered);
        assert!(Variant::CausalScaled.is_causal());
        assert!(!Variant::Decode.is_causal() && Variant::Decode.is_decode());
        assert_eq!(Variant::CausalReordered.mask(), Mask::Causal);
        assert_eq!(Variant::Naive.mask(), Mask::Full);
        // The PAPER set is exactly the unmasked prefill family.
        for v in Variant::PAPER {
            assert_eq!(v.base(), v);
            assert!(!v.is_causal() && !v.is_decode());
        }
    }

    #[test]
    fn masked_frontend_emits_neg_inf_outside_the_mask() {
        let w = Workload::random(4, 3, 22);
        let mut g = GraphBuilder::new();
        let mut sc = g.root();
        let s = score_frontend_masked(&mut sc, &w, &Mask::Causal).unwrap();
        let h = sc.sink("sink", s, Some(16)).unwrap();
        let mut e = g.build().unwrap();
        e.run(10_000).unwrap();
        let got = h.scalars();
        for i in 0..4 {
            for j in 0..4 {
                let x = got[i * 4 + j];
                if j <= i {
                    assert!((x - w.score(i, j)).abs() < 1e-6, "visible ({i},{j})");
                } else {
                    assert_eq!(x, f32::NEG_INFINITY, "masked ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn paper_plan_depths() {
        let p = FifoPlan::paper(64);
        assert_eq!(p.short, Capacity::Bounded(2));
        assert_eq!(p.long, Capacity::Bounded(66));
    }

    #[test]
    fn shared_cycle_budget_used_by_built_graphs() {
        let w = Workload::random(8, 4, 1);
        let built = Variant::MemoryFree.build_inferred(&w).unwrap();
        assert_eq!(built.default_budget(), cycle_budget(8));
    }

    #[test]
    fn score_frontend_streams_row_major_scores() {
        let w = Workload::random(4, 3, 21);
        let mut g = GraphBuilder::new();
        let mut sc = g.root();
        let s = score_frontend(&mut sc, &w).unwrap();
        let h = sc.sink("sink", s, Some(16)).unwrap();
        let mut e = g.build().unwrap();
        e.run(10_000).unwrap();
        let got = h.scalars();
        assert_eq!(got.len(), 16);
        for i in 0..4 {
            for j in 0..4 {
                assert!(
                    (got[i * 4 + j] - w.score(i, j)).abs() < 1e-6,
                    "score ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn frontend_full_throughput_at_depth_2() {
        let w = Workload::random(16, 4, 2);
        let mut g = GraphBuilder::new();
        let mut sc = g.root();
        let s = score_frontend(&mut sc, &w).unwrap();
        let h = sc.sink("sink", s, Some(256)).unwrap();
        let mut e = g.build().unwrap();
        // The front-end has no reconvergent paths: inference keeps
        // every FIFO at depth 2 and the stream still runs at II=1.
        assert!(e.depth_report().iter().all(|c| !c.is_long));
        e.run(100_000).unwrap();
        assert_eq!(h.arrival_gaps(128), Some((1, 1)), "II=1 steady state");
    }
}
