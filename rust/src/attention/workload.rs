//! Deterministic Q/K/V workload generation.
//!
//! The paper's experiments are driven by the sequence length `N` and head
//! dimension `d`; the actual values only matter for numeric validation
//! against the reference, so we generate them from a seeded PRNG
//! (reproducible across runs, required for `Engine::reset` replays).

use crate::prng::SplitMix64;

/// One attention head's worth of inputs: Q, K, V ∈ ℝ^{N×d}, row-major.
#[derive(Clone, Debug)]
pub struct Workload {
    /// Sequence length (number of tokens).
    pub n: usize,
    /// Head dimension.
    pub d: usize,
    /// Query rows.
    pub q: Vec<Vec<f32>>,
    /// Key rows (the graphs stream columns of Kᵀ = rows of K).
    pub k: Vec<Vec<f32>>,
    /// Value rows.
    pub v: Vec<Vec<f32>>,
}

impl Workload {
    /// Random normal workload (the distribution real QKV projections
    /// approximate at init; softmax inputs land in a realistic range
    /// once scaled by 1/√d).
    pub fn random(n: usize, d: usize, seed: u64) -> Self {
        assert!(n >= 1 && d >= 1);
        let mut rng = SplitMix64::new(seed);
        let mut mat = |_| (0..n).map(|_| rng.normal_vec(d)).collect::<Vec<_>>();
        Workload {
            n,
            d,
            q: mat(0),
            k: mat(1),
            v: mat(2),
        }
    }

    /// Adversarial workload for numerical-stability tests: scores span a
    /// huge dynamic range so the unscaled (no max subtraction) softmax
    /// overflows f32 while the scaled variants stay finite.
    pub fn large_magnitude(n: usize, d: usize, seed: u64, scale: f32) -> Self {
        let mut w = Self::random(n, d, seed);
        for row in w.q.iter_mut() {
            for x in row.iter_mut() {
                *x *= scale;
            }
        }
        w
    }

    /// The softmax scale factor 1/√d used by every variant.
    pub fn scale(&self) -> f32 {
        1.0 / (self.d as f32).sqrt()
    }

    /// Scaled score s_ij = (q_i · k_j) / √d (f32 accumulation, the same
    /// order the dataflow graphs use — bit-compatible with the sim).
    pub fn score(&self, i: usize, j: usize) -> f32 {
        dot(&self.q[i], &self.k[j]) * self.scale()
    }
}

/// f32 dot product (sequential accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Workload::random(8, 4, 1);
        let b = Workload::random(8, 4, 1);
        let c = Workload::random(8, 4, 2);
        assert_eq!(a.q, b.q);
        assert_eq!(a.v, b.v);
        assert_ne!(a.q, c.q);
    }

    #[test]
    fn shapes_match() {
        let w = Workload::random(5, 3, 0);
        assert_eq!(w.q.len(), 5);
        assert!(w.q.iter().all(|r| r.len() == 3));
        assert_eq!(w.k.len(), 5);
        assert_eq!(w.v.len(), 5);
    }

    #[test]
    fn scale_is_inv_sqrt_d() {
        let w = Workload::random(2, 16, 0);
        assert!((w.scale() - 0.25).abs() < 1e-7);
    }

    #[test]
    fn score_matches_manual_dot() {
        let w = Workload::random(4, 4, 3);
        let manual = dot(&w.q[1], &w.k[2]) / 2.0;
        assert_eq!(w.score(1, 2), manual);
    }

    #[test]
    fn large_magnitude_scales_q() {
        let base = Workload::random(4, 4, 9);
        let big = Workload::large_magnitude(4, 4, 9, 100.0);
        assert!((big.q[0][0] - base.q[0][0] * 100.0).abs() < 1e-3);
        assert_eq!(big.k, base.k);
    }
}
