//! Deterministic Q/K/V workload generation and attention masks.
//!
//! The paper's experiments are driven by the sequence length `N` and head
//! dimension `d`; the actual values only matter for numeric validation
//! against the reference, so we generate them from a seeded PRNG
//! (reproducible across runs, required for `Engine::reset` replays).
//!
//! [`Mask`] describes which score positions are visible — full
//! (prefill), causal (autoregressive), ragged-causal (a padded
//! sequence whose valid length is shorter than `N`), or sliding-window
//! causal ([`Mask::Window`]: row `i` sees only its last `w` keys). The
//! visible set of every mask is one contiguous span per row,
//! [`Mask::row_span`]. The prefix masks (everything but `Window`) start
//! that span at key 0, so the memory-free running-max scan is seeded
//! before any masked position arrives; a window mask starts the span at
//! `i + 1 − w`, which is why the scan carries an explicit unseeded
//! guard (see [`super::memfree`]). Every mask keeps the diagonal
//! visible, so no row's softmax is over an empty set.

use crate::prng::SplitMix64;

/// Which `(query row, key)` score positions are visible.
///
/// Every mask keeps the diagonal visible to every row (softmax over an
/// empty set is undefined), and every row's visible set is one
/// contiguous span ([`Mask::row_span`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Mask {
    /// Every row attends every key — the paper's prefill setting.
    Full,
    /// Row `i` attends keys `j ≤ i` — autoregressive attention.
    Causal,
    /// Causal attention over a padded sequence whose valid length is
    /// `len` (< N typically): keys at `j ≥ len` are padding and masked
    /// for every row; padding query rows (`i ≥ len`) attend the whole
    /// valid prefix, so their outputs are well-defined but ignorable.
    Ragged {
        /// Valid sequence length (≥ 1).
        len: usize,
    },
    /// Sliding-window causal attention: row `i` attends only its last
    /// `w` keys, `max(0, i + 1 − w) ≤ j ≤ i`. The only non-prefix mask
    /// (key 0 is invisible once `i ≥ w`), and the attention semantic
    /// that makes a decode session's KV footprint O(w) — see
    /// `runtime::kvcache`'s windowed block eviction.
    Window {
        /// Window width in keys (≥ 1; `w = 1` means diagonal-only).
        w: usize,
    },
}

impl Mask {
    /// Ragged-causal mask for a valid length (must be ≥ 1).
    pub fn ragged(len: usize) -> Mask {
        assert!(len >= 1, "ragged mask needs a valid length of at least 1");
        Mask::Ragged { len }
    }

    /// Sliding-window causal mask of width `w` (must be ≥ 1).
    pub fn window(w: usize) -> Mask {
        assert!(w >= 1, "window mask needs a width of at least 1");
        Mask::Window { w }
    }

    /// Whether score `(i, j)` is visible.
    #[inline]
    pub fn visible(&self, i: usize, j: usize) -> bool {
        match *self {
            Mask::Full => true,
            Mask::Causal => j <= i,
            Mask::Ragged { len } => {
                if i < len {
                    j <= i
                } else {
                    j < len
                }
            }
            Mask::Window { w } => j <= i && j + w > i,
        }
    }

    /// The visible span of row `i` in an `n`-key sequence, as a
    /// half-open `(start, end)` key range. Prefix masks start at 0; the
    /// window mask starts at `i + 1 − w`. The masked references (and
    /// the windowed decode mapping) iterate exactly this span, in
    /// stream order.
    pub fn row_span(&self, i: usize, n: usize) -> (usize, usize) {
        match *self {
            Mask::Window { w } => (((i + 1).saturating_sub(w)).min(n), (i + 1).min(n)),
            _ => (0, self.row_visible(i, n)),
        }
    }

    /// Number of visible keys in row `i` of an `n`-key sequence
    /// (`row_span` length).
    pub fn row_visible(&self, i: usize, n: usize) -> usize {
        match *self {
            Mask::Full => n,
            Mask::Causal => (i + 1).min(n),
            Mask::Ragged { len } => (i + 1).min(len).min(n),
            Mask::Window { .. } => {
                let (start, end) = self.row_span(i, n);
                end - start
            }
        }
    }

    /// Stable name for reports.
    pub fn name(&self) -> String {
        match self {
            Mask::Full => "full".into(),
            Mask::Causal => "causal".into(),
            Mask::Ragged { len } => format!("ragged({len})"),
            Mask::Window { w } => format!("window({w})"),
        }
    }
}

/// One attention head's worth of inputs: Q, K, V ∈ ℝ^{N×d}, row-major.
#[derive(Clone, Debug)]
pub struct Workload {
    /// Sequence length (number of tokens).
    pub n: usize,
    /// Head dimension.
    pub d: usize,
    /// Query rows.
    pub q: Vec<Vec<f32>>,
    /// Key rows (the graphs stream columns of Kᵀ = rows of K).
    pub k: Vec<Vec<f32>>,
    /// Value rows.
    pub v: Vec<Vec<f32>>,
}

impl Workload {
    /// Random normal workload (the distribution real QKV projections
    /// approximate at init; softmax inputs land in a realistic range
    /// once scaled by 1/√d).
    pub fn random(n: usize, d: usize, seed: u64) -> Self {
        assert!(n >= 1 && d >= 1);
        let mut rng = SplitMix64::new(seed);
        let mut mat = |_| (0..n).map(|_| rng.normal_vec(d)).collect::<Vec<_>>();
        Workload {
            n,
            d,
            q: mat(0),
            k: mat(1),
            v: mat(2),
        }
    }

    /// Adversarial workload for numerical-stability tests: scores span a
    /// huge dynamic range so the unscaled (no max subtraction) softmax
    /// overflows f32 while the scaled variants stay finite.
    pub fn large_magnitude(n: usize, d: usize, seed: u64, scale: f32) -> Self {
        let mut w = Self::random(n, d, seed);
        for row in w.q.iter_mut() {
            for x in row.iter_mut() {
                *x *= scale;
            }
        }
        w
    }

    /// The softmax scale factor 1/√d used by every variant.
    pub fn scale(&self) -> f32 {
        1.0 / (self.d as f32).sqrt()
    }

    /// Scaled score s_ij = (q_i · k_j) / √d (f32 accumulation, the same
    /// order the dataflow graphs use — bit-compatible with the sim).
    pub fn score(&self, i: usize, j: usize) -> f32 {
        dot(&self.q[i], &self.k[j]) * self.scale()
    }

    /// The first `len` tokens of this workload (1 ≤ len ≤ N) — ragged
    /// sequences and decode-session prefixes are truncations.
    pub fn prefix(&self, len: usize) -> Workload {
        assert!(len >= 1 && len <= self.n, "prefix length out of range");
        Workload {
            n: len,
            d: self.d,
            q: self.q[..len].to_vec(),
            k: self.k[..len].to_vec(),
            v: self.v[..len].to_vec(),
        }
    }
}

/// f32 dot product (sequential accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = Workload::random(8, 4, 1);
        let b = Workload::random(8, 4, 1);
        let c = Workload::random(8, 4, 2);
        assert_eq!(a.q, b.q);
        assert_eq!(a.v, b.v);
        assert_ne!(a.q, c.q);
    }

    #[test]
    fn shapes_match() {
        let w = Workload::random(5, 3, 0);
        assert_eq!(w.q.len(), 5);
        assert!(w.q.iter().all(|r| r.len() == 3));
        assert_eq!(w.k.len(), 5);
        assert_eq!(w.v.len(), 5);
    }

    #[test]
    fn scale_is_inv_sqrt_d() {
        let w = Workload::random(2, 16, 0);
        assert!((w.scale() - 0.25).abs() < 1e-7);
    }

    #[test]
    fn score_matches_manual_dot() {
        let w = Workload::random(4, 4, 3);
        let manual = dot(&w.q[1], &w.k[2]) / 2.0;
        assert_eq!(w.score(1, 2), manual);
    }

    #[test]
    fn large_magnitude_scales_q() {
        let base = Workload::random(4, 4, 9);
        let big = Workload::large_magnitude(4, 4, 9, 100.0);
        assert!((big.q[0][0] - base.q[0][0] * 100.0).abs() < 1e-3);
        assert_eq!(big.k, base.k);
    }

    #[test]
    fn prefix_truncates_all_three_operands() {
        let w = Workload::random(8, 4, 12);
        let p = w.prefix(3);
        assert_eq!(p.n, 3);
        assert_eq!(p.q, w.q[..3].to_vec());
        assert_eq!(p.k, w.k[..3].to_vec());
        assert_eq!(p.v, w.v[..3].to_vec());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn prefix_rejects_zero_length() {
        Workload::random(4, 4, 1).prefix(0);
    }

    #[test]
    fn causal_mask_is_lower_triangular() {
        let m = Mask::Causal;
        assert!(m.visible(3, 0) && m.visible(3, 3));
        assert!(!m.visible(3, 4));
        assert_eq!(m.row_visible(0, 8), 1);
        assert_eq!(m.row_visible(7, 8), 8);
    }

    #[test]
    fn ragged_mask_clamps_to_valid_length() {
        let m = Mask::ragged(3);
        // Real rows: causal within the valid prefix.
        assert!(m.visible(1, 1) && !m.visible(1, 2));
        // Padding rows attend the whole valid prefix, nothing beyond.
        assert!(m.visible(5, 2) && !m.visible(5, 3));
        assert_eq!(m.row_visible(1, 8), 2);
        assert_eq!(m.row_visible(5, 8), 3);
    }

    #[test]
    fn every_mask_keeps_the_diagonal_visible() {
        for m in [
            Mask::Full,
            Mask::Causal,
            Mask::ragged(1),
            Mask::ragged(5),
            Mask::window(1),
            Mask::window(4),
        ] {
            for i in 0..10 {
                let diag = if let Mask::Ragged { len } = m {
                    i.min(len - 1)
                } else {
                    i
                };
                assert!(m.visible(i, diag), "{} row {i}", m.name());
                assert!(m.row_visible(i, 10) >= 1, "{} row {i}", m.name());
            }
        }
    }

    #[test]
    fn window_mask_slides_and_caps_row_visibility() {
        let m = Mask::window(3);
        // Early rows: plain causal (window not yet full).
        assert!(m.visible(1, 0) && m.visible(1, 1) && !m.visible(1, 2));
        assert_eq!(m.row_span(1, 8), (0, 2));
        // Steady state: exactly the last 3 keys.
        assert!(!m.visible(5, 2) && m.visible(5, 3) && m.visible(5, 5));
        assert!(!m.visible(5, 6), "future keys stay masked");
        assert_eq!(m.row_span(5, 8), (3, 6));
        assert_eq!(m.row_visible(5, 8), 3);
        // w = 1 is diagonal-only.
        let d = Mask::window(1);
        assert!(d.visible(4, 4) && !d.visible(4, 3) && !d.visible(4, 5));
        assert_eq!(d.row_span(4, 8), (4, 5));
    }

    #[test]
    fn prefix_masks_span_from_key_zero() {
        for m in [Mask::Full, Mask::Causal, Mask::ragged(3)] {
            for i in 0..6 {
                let (start, end) = m.row_span(i, 6);
                assert_eq!(start, 0, "{} row {i}", m.name());
                assert_eq!(end, m.row_visible(i, 6), "{} row {i}", m.name());
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn ragged_mask_rejects_zero() {
        Mask::ragged(0);
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn window_mask_rejects_zero() {
        Mask::window(0);
    }
}
