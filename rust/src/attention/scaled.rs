//! Figure 3(a) — attention with softmax-with-scaling (row max).
//!
//! Numerically stable softmax subtracts the row max before
//! exponentiating. On the abstract hardware this adds a *second*
//! reduction (`row_max`) and with it a second pair of divergent paths:
//!
//! ```text
//! s ─ Broadcast ─→ Reduce(N, −∞, max) → Repeat(N) ─┐
//!        └─ s_bypass (LONG FIFO #1) ──────→ Zip(exp(s−m)) → e
//! e ─ Broadcast ─→ Reduce(N, 0, +) → Repeat(N) ─┐
//!        └─ e_bypass (LONG FIFO #2) ──────→ Zip(÷) → p → PV tail
//! ```
//!
//! Both `s_bypass` and `e_bypass` must be ~N deep for full throughput —
//! this variant makes the memory problem *worse* before Figure 3(b)/(c)
//! make it better, exactly as the paper narrates. The depth analysis
//! flags both channels and sizes each at N+2.

use super::workload::{Mask, Workload};
use super::{pv_tail, score_frontend_masked, BuiltAttention, DepthPolicy, FifoPlan};
use crate::sim::{Elem, GraphBuilder};
use crate::Result;

/// Build the Figure-3(a) graph. Both long FIFOs take `plan.long`.
pub fn build(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_with_policy(w, DepthPolicy::Explicit(*plan))
}

/// Figure-3(a) graph under a depth policy (`Inferred` derives N+2 for
/// both bypasses).
pub fn build_with_policy(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_masked_with_policy(w, &Mask::Full, policy)
}

/// Figure-3(a) graph with an in-stream [`Mask`]. Masked scores enter
/// the row-max reduction as −∞ (a no-op under `max`, since key 0 is
/// always visible) and the exponential as e = 0; timing, and therefore
/// both N+2 bypass bounds, are unchanged.
pub fn build_masked_with_policy(
    w: &Workload,
    mask: &Mask,
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let n = w.n;
    let mut g = GraphBuilder::new();
    let mut sc = g.root();

    let s = score_frontend_masked(&mut sc, w, mask)?;

    // First divergence: row max vs score bypass.
    let [s_max, s_bypass] = sc.broadcast("bc_s", s, ["s_max", "s_bypass"])?;
    let m = sc.reduce("row_max", s_max, n, f32::NEG_INFINITY, f32::max)?;
    let m_rep = sc.repeat("rep_m", m, n)?;

    // e_ij = exp(s_ij − m_i).
    let e = sc.zip("exp_sub", [s_bypass, m_rep], |xs| {
        Elem::Scalar((xs[0].scalar() - xs[1].scalar()).exp())
    })?;

    // Second divergence: row sum vs exponential bypass.
    let [e_sum, e_bypass] = sc.broadcast("bc_e", e, ["e_sum", "e_bypass"])?;
    let sigma = sc.reduce("row_sum", e_sum, n, 0.0, |a, b| a + b)?;
    let sigma_rep = sc.repeat("rep_sigma", sigma, n)?;

    let p = sc.zip("div", [e_bypass, sigma_rep], |xs| {
        Elem::Scalar(xs[0].scalar() / xs[1].scalar())
    })?;

    let out = pv_tail(&mut sc, w, p)?;
    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n,
        d: w.d,
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f32_scaled, sdpa_f64};
    use super::super::FifoPlan;
    use super::*;
    use crate::sim::metrics::is_full_throughput;
    use crate::sim::RunOutcome;

    #[test]
    fn matches_reference_numerics() {
        let w = Workload::random(12, 8, 200);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(&got, &sdpa_f32_scaled(&w), 1e-5, "scaled vs f32 ref");
        assert_close(&got, &sdpa_f64(&w), 1e-4, "scaled vs f64 ref");
    }

    #[test]
    fn survives_adversarial_magnitudes() {
        // The whole point of softmax-with-scaling: no overflow where the
        // naive algorithm produces NaN.
        let w = Workload::large_magnitude(8, 4, 9, 200.0);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert!(got.iter().flatten().all(|x| x.is_finite()));
        assert_close(&got, &sdpa_f64(&w), 1e-4, "scaled adversarial");
    }

    #[test]
    fn paper_config_achieves_full_throughput() {
        let w = Workload::random(16, 4, 13);
        let mut finite = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, s_finite) = finite.run().unwrap();
        let mut base = build(&w, &FifoPlan::unbounded()).unwrap();
        let (_, s_base) = base.run().unwrap();
        assert!(is_full_throughput(&s_finite, &s_base));
    }

    #[test]
    fn both_bypasses_are_order_n() {
        let w = Workload::random(16, 4, 14);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        for fifo in ["s_bypass", "e_bypass"] {
            let peak = summary.peak_elems(fifo).unwrap();
            assert!(
                peak >= w.n - 1 && peak <= w.n + 2,
                "{fifo} peak {} for N={}",
                peak,
                w.n
            );
        }
    }

    #[test]
    fn inference_flags_both_bypasses() {
        let w = Workload::random(16, 4, 14);
        let built = build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        for fifo in ["s_bypass", "e_bypass"] {
            let rec = built
                .engine
                .depth_report()
                .iter()
                .find(|c| c.name == fifo)
                .unwrap();
            assert!(rec.is_long, "{fifo}");
            assert_eq!(rec.inferred, w.n + 2, "{fifo}");
        }
    }

    #[test]
    fn undersized_either_bypass_deadlocks() {
        let w = Workload::random(12, 4, 15);
        // Both long FIFOs too shallow.
        let mut built = build(&w, &FifoPlan::with_long_depth(3)).unwrap();
        assert!(matches!(
            built.run_outcome().outcome,
            RunOutcome::Deadlock { .. }
        ));
        // Only s_bypass undersized (e_bypass generous).
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        built
            .engine
            .set_capacity("s_bypass", crate::sim::Capacity::Bounded(3))
            .unwrap();
        assert!(matches!(
            built.run_outcome().outcome,
            RunOutcome::Deadlock { .. }
        ));
    }
}
