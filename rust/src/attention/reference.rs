//! Golden reference SDPA implementations.
//!
//! Three reference families, used to validate every dataflow graph and
//! (via the Python `ref.py` twin) the Pallas kernel:
//!
//! * [`sdpa_f64`] — naive softmax attention in f64, the accuracy oracle.
//! * [`sdpa_f32_unscaled`] — softmax **without** max subtraction, f32 —
//!   matches the paper's §3 naive algorithm bit-for-bit in structure
//!   (overflows for large scores, which the stability tests rely on).
//! * [`sdpa_online_f32`] — the §4 memory-free recurrence (Eq. 3–6)
//!   executed sequentially; validates the algorithm itself independent
//!   of the dataflow mapping.
//!
//! Each has a `_masked` twin taking a [`Mask`]: row `i` folds only its
//! visible key span `mask.row_span(i, n)`, in stream order — so the
//! masked online reference executes the *same f32 operation sequence*
//! as a decode-step chain and as the masked graphs' visible positions
//! (masked stream slots reduce to exact identity updates: `Δ = 1`,
//! `e = 0` once the running max is seeded, and `Δ = e = 0` before — see
//! the unseeded guard in [`super::memfree`]). For the prefix masks the
//! span starts at key 0; for [`Mask::Window`] it starts at `i + 1 − w`,
//! which is also exactly the truncated row a windowed decode step
//! streams.

use super::workload::{Mask, Workload};

/// Output matrix, row-major `n × d`.
pub type Matrix = Vec<Vec<f32>>;

/// f64 naive attention with max-subtracted (scaled) softmax.
pub fn sdpa_f64(w: &Workload) -> Matrix {
    let scale = w.scale() as f64;
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let s: Vec<f64> = (0..w.n)
            .map(|j| {
                w.q[i]
                    .iter()
                    .zip(&w.k[j])
                    .map(|(a, b)| *a as f64 * *b as f64)
                    .sum::<f64>()
                    * scale
            })
            .collect();
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = s.iter().map(|x| (x - m).exp()).collect();
        let sigma: f64 = e.iter().sum();
        let mut row = vec![0.0f64; w.d];
        for j in 0..w.n {
            let p = e[j] / sigma;
            for (acc, vv) in row.iter_mut().zip(&w.v[j]) {
                *acc += p * *vv as f64;
            }
        }
        out.push(row.into_iter().map(|x| x as f32).collect());
    }
    out
}

/// f32 naive attention, softmax **without** max subtraction — the exact
/// algorithm the Figure-2 graph implements.
pub fn sdpa_f32_unscaled(w: &Workload) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let e: Vec<f32> = (0..w.n).map(|j| w.score(i, j).exp()).collect();
        let sigma: f32 = e.iter().sum();
        let mut row = vec![0.0f32; w.d];
        for j in 0..w.n {
            let p = e[j] / sigma;
            for (acc, vv) in row.iter_mut().zip(&w.v[j]) {
                *acc += p * vv;
            }
        }
        out.push(row);
    }
    out
}

/// f32 naive attention with max-subtracted softmax — the algorithm the
/// Figure-3(a)/(b) graphs implement.
pub fn sdpa_f32_scaled(w: &Workload) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let s: Vec<f32> = (0..w.n).map(|j| w.score(i, j)).collect();
        let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = s.iter().map(|x| (x - m).exp()).collect();
        let sigma: f32 = e.iter().sum();
        let mut row = vec![0.0f32; w.d];
        for j in 0..w.n {
            let p = e[j] / sigma;
            for (acc, vv) in row.iter_mut().zip(&w.v[j]) {
                *acc += p * vv;
            }
        }
        out.push(row);
    }
    out
}

/// The paper's memory-free recurrence (Eq. 3–6), run sequentially:
/// running max `m`, rescale `Δ = e^{m_old − m_new}`, running sum
/// `r ← r·Δ + e`, running output `l⃗ ← l⃗·Δ + e·v⃗`, final `o⃗ = l⃗/r`.
pub fn sdpa_online_f32(w: &Workload) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let mut m = f32::NEG_INFINITY;
        let mut r = 0.0f32;
        let mut l = vec![0.0f32; w.d];
        for j in 0..w.n {
            let s = w.score(i, j);
            let m_new = m.max(s);
            let delta = (m - m_new).exp(); // e^{-inf - m} = 0 on the first step
            let e = (s - m_new).exp();
            r = r * delta + e;
            for (acc, vv) in l.iter_mut().zip(&w.v[j]) {
                *acc = *acc * delta + e * vv;
            }
            m = m_new;
        }
        out.push(l.into_iter().map(|x| x / r).collect());
    }
    out
}

/// f64 causal (autoregressive) attention: row i attends keys 0..=i.
pub fn sdpa_f64_causal(w: &Workload) -> Matrix {
    sdpa_f64_masked(w, &Mask::Causal)
}

/// f64 masked attention: row i folds its visible key span only.
pub fn sdpa_f64_masked(w: &Workload, mask: &Mask) -> Matrix {
    let scale = w.scale() as f64;
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let (start, end) = mask.row_span(i, w.n);
        let s: Vec<f64> = (start..end)
            .map(|j| {
                w.q[i]
                    .iter()
                    .zip(&w.k[j])
                    .map(|(a, b)| *a as f64 * *b as f64)
                    .sum::<f64>()
                    * scale
            })
            .collect();
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let e: Vec<f64> = s.iter().map(|x| (x - m).exp()).collect();
        let sigma: f64 = e.iter().sum();
        let mut row = vec![0.0f64; w.d];
        for (ej, j) in e.iter().zip(start..end) {
            let p = ej / sigma;
            for (acc, vv) in row.iter_mut().zip(&w.v[j]) {
                *acc += p * *vv as f64;
            }
        }
        out.push(row.into_iter().map(|x| x as f32).collect());
    }
    out
}

/// f32 unscaled-softmax attention over the visible span — what the
/// masked Figure-2 graph computes (masked slots contribute e = 0).
pub fn sdpa_f32_unscaled_masked(w: &Workload, mask: &Mask) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let (start, end) = mask.row_span(i, w.n);
        let e: Vec<f32> = (start..end).map(|j| w.score(i, j).exp()).collect();
        let sigma: f32 = e.iter().sum();
        let mut row = vec![0.0f32; w.d];
        for (ej, j) in e.iter().zip(start..end) {
            let p = ej / sigma;
            for (acc, vv) in row.iter_mut().zip(&w.v[j]) {
                *acc += p * vv;
            }
        }
        out.push(row);
    }
    out
}

/// f32 max-subtracted-softmax attention over the visible span — what
/// the masked Figure-3(a)/(b) graphs compute (the row max over the full
/// stream equals the max over the visible span, since masked scores
/// enter as −∞).
pub fn sdpa_f32_scaled_masked(w: &Workload, mask: &Mask) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let (start, end) = mask.row_span(i, w.n);
        let s: Vec<f32> = (start..end).map(|j| w.score(i, j)).collect();
        let m = s.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = s.iter().map(|x| (x - m).exp()).collect();
        let sigma: f32 = e.iter().sum();
        let mut row = vec![0.0f32; w.d];
        for (ej, j) in e.iter().zip(start..end) {
            let p = ej / sigma;
            for (acc, vv) in row.iter_mut().zip(&w.v[j]) {
                *acc += p * vv;
            }
        }
        out.push(row);
    }
    out
}

/// The memory-free recurrence over the visible span — the incremental
/// decode oracle. Step `t` of an autoregressive decode session executes
/// exactly this row-`t` loop (same f32 operations, same order; a
/// windowed session streams exactly the span's rows), so a decode-step
/// chain must agree with this reference essentially bit-for-bit.
pub fn sdpa_online_f32_masked(w: &Workload, mask: &Mask) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let (start, end) = mask.row_span(i, w.n);
        let mut m = f32::NEG_INFINITY;
        let mut r = 0.0f32;
        let mut l = vec![0.0f32; w.d];
        for j in start..end {
            let s = w.score(i, j);
            let m_new = m.max(s);
            let delta = (m - m_new).exp();
            let e = (s - m_new).exp();
            r = r * delta + e;
            for (acc, vv) in l.iter_mut().zip(&w.v[j]) {
                *acc = *acc * delta + e * vv;
            }
            m = m_new;
        }
        out.push(l.into_iter().map(|x| x / r).collect());
    }
    out
}

/// The FLASH-D hidden-division recurrence, run sequentially: running
/// log-sum-exp `t ← max(t,s) + ln_1p(e^{−|t−s|})`, normalized weight
/// `w = e^{s−t}`, output EMA `o⃗ ← o⃗ + w·(v⃗ − o⃗)` — no division
/// anywhere, the output is normalized at every step. Validates the
/// algorithm itself independent of the dataflow mapping (the
/// structure-matched oracle for [`super::flashd`]).
pub fn sdpa_flashd_f32(w: &Workload) -> Matrix {
    sdpa_flashd_f32_masked(w, &Mask::Full)
}

/// [`sdpa_flashd_f32`] over the visible span — the FLASH-D decode
/// oracle. Step `t` of a FLASH-D decode session executes exactly this
/// row-`t` loop (the shared [`super::flashd::lse_fold`] /
/// `hidden_weight` helpers: same f32 operations, same order), so a
/// FLASH-D decode-step chain must agree with this reference essentially
/// bit-for-bit.
pub fn sdpa_flashd_f32_masked(w: &Workload, mask: &Mask) -> Matrix {
    let mut out = Vec::with_capacity(w.n);
    for i in 0..w.n {
        let (start, end) = mask.row_span(i, w.n);
        let mut t = f32::NEG_INFINITY;
        let mut o = vec![0.0f32; w.d];
        for j in start..end {
            let s = w.score(i, j);
            let t_new = super::flashd::lse_fold(t, s);
            let wgt = super::flashd::hidden_weight(s, t_new);
            for (acc, vv) in o.iter_mut().zip(&w.v[j]) {
                *acc += wgt * (vv - *acc);
            }
            t = t_new;
        }
        out.push(o);
    }
    out
}

/// Max absolute element-wise difference between two matrices.
pub fn max_abs_diff(a: &Matrix, b: &Matrix) -> f32 {
    assert_eq!(a.len(), b.len(), "row count mismatch");
    let mut worst = 0.0f32;
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.len(), rb.len(), "row width mismatch");
        for (x, y) in ra.iter().zip(rb) {
            let diff = (x - y).abs();
            if diff.is_nan() {
                return f32::NAN;
            }
            worst = worst.max(diff);
        }
    }
    worst
}

/// Assert two matrices agree within `tol`, with a useful failure message.
pub fn assert_close(a: &Matrix, b: &Matrix, tol: f32, what: &str) {
    let diff = max_abs_diff(a, b);
    assert!(
        diff.is_finite() && diff <= tol,
        "{what}: max |Δ| = {diff} exceeds tol {tol}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_references_agree_on_random_input() {
        let w = Workload::random(16, 8, 42);
        let gold = sdpa_f64(&w);
        assert_close(&sdpa_f32_scaled(&w), &gold, 2e-5, "scaled vs f64");
        assert_close(&sdpa_f32_unscaled(&w), &gold, 2e-5, "unscaled vs f64");
        assert_close(&sdpa_online_f32(&w), &gold, 2e-5, "online vs f64");
    }

    #[test]
    fn online_recurrence_handles_descending_scores() {
        // Running max never updates after the first element: Δ stays 1.
        let mut w = Workload::random(8, 4, 7);
        // Force q rows so scores descend: score(i, j) = -(j); easiest is
        // to just check agreement, which covers the branch.
        w.q[0] = vec![3.0; 4];
        assert_close(&sdpa_online_f32(&w), &sdpa_f64(&w), 3e-5, "online");
    }

    #[test]
    fn unscaled_softmax_overflows_on_adversarial_input() {
        let w = Workload::large_magnitude(8, 4, 3, 200.0);
        let naive = sdpa_f32_unscaled(&w);
        let any_nonfinite = naive.iter().flatten().any(|x| !x.is_finite());
        assert!(any_nonfinite, "expected overflow in unscaled softmax");
        // The scaled / online versions stay finite — the reason the paper
        // uses softmax-with-scaling (§4).
        assert!(sdpa_f32_scaled(&w).iter().flatten().all(|x| x.is_finite()));
        assert!(sdpa_online_f32(&w).iter().flatten().all(|x| x.is_finite()));
    }

    #[test]
    fn softmax_rows_produce_convex_combinations() {
        // Each output row must lie within the [min, max] envelope of V's
        // columns (softmax weights are a convex combination).
        let w = Workload::random(12, 6, 11);
        let out = sdpa_f64(&w);
        for col in 0..w.d {
            let lo = w.v.iter().map(|r| r[col]).fold(f32::INFINITY, f32::min);
            let hi = w.v.iter().map(|r| r[col]).fold(f32::NEG_INFINITY, f32::max);
            for row in &out {
                assert!(row[col] >= lo - 1e-5 && row[col] <= hi + 1e-5);
            }
        }
    }

    #[test]
    fn single_token_sequence_returns_v() {
        let w = Workload::random(1, 4, 5);
        let out = sdpa_f64(&w);
        for (a, b) in out[0].iter().zip(&w.v[0]) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_first_row_is_v0_and_last_row_uses_all_keys() {
        let w = Workload::random(8, 4, 21);
        let causal = sdpa_f64_causal(&w);
        for (a, b) in causal[0].iter().zip(&w.v[0]) {
            assert!((a - b).abs() < 1e-6, "row 0 attends only key 0");
        }
        // Last row sees every key: equals the unmasked attention row.
        let full = sdpa_f64(&w);
        for (a, b) in causal[7].iter().zip(&full[7]) {
            assert!((a - b).abs() < 1e-6, "last row equals full attention");
        }
    }

    #[test]
    fn flashd_recurrence_agrees_with_the_oracles_on_every_mask() {
        let w = Workload::random(12, 6, 88);
        for mask in [Mask::Full, Mask::Causal, Mask::ragged(5), Mask::window(4)] {
            let gold = sdpa_f64_masked(&w, &mask);
            assert_close(
                &sdpa_flashd_f32_masked(&w, &mask),
                &gold,
                3e-5,
                &format!("flashd masked {}", mask.name()),
            );
        }
        assert_eq!(sdpa_flashd_f32_masked(&w, &Mask::Full), sdpa_flashd_f32(&w));
    }

    #[test]
    fn flashd_is_normalized_at_every_prefix() {
        // The hidden-division property: the EMA state is a convex
        // combination of the V rows folded so far, at *every* step —
        // which is why no final divide exists. Check via prefixes: the
        // masked recurrence over ragged(len) rows equals full-span
        // flashd of the truncated workload on the valid rows.
        let w = Workload::random(8, 4, 89);
        for len in [1usize, 3, 8] {
            let ragged = sdpa_flashd_f32_masked(&w, &Mask::ragged(len));
            let trunc = sdpa_flashd_f32_masked(&w.prefix(len), &Mask::Causal);
            for i in 0..len {
                assert_eq!(ragged[i], trunc[i], "len={len} row {i}");
            }
        }
    }

    #[test]
    fn flashd_survives_adversarial_magnitudes() {
        // w ≤ 1 and the EMA is bounded by V's envelope: no overflow on
        // the inputs that blow up the unscaled naive softmax.
        let w = Workload::large_magnitude(8, 4, 90, 200.0);
        let out = sdpa_flashd_f32(&w);
        assert!(out.iter().flatten().all(|x| x.is_finite()));
        assert_close(&out, &sdpa_f64(&w), 1e-4, "flashd adversarial");
    }

    #[test]
    fn max_abs_diff_detects_nan() {
        let a = vec![vec![f32::NAN]];
        let b = vec![vec![0.0]];
        assert!(max_abs_diff(&a, &b).is_nan());
    }

    #[test]
    fn windowed_reference_matches_truncated_full_attention() {
        // Row i under Window(w) is full attention of q_i over exactly
        // keys/values [i+1−w, i] — the truncation oracle.
        let w = Workload::random(10, 4, 0x31AB);
        let win = 3usize;
        let masked = sdpa_f64_masked(&w, &Mask::window(win));
        for i in 0..w.n {
            let start = (i + 1).saturating_sub(win);
            let mut wt = Workload {
                n: i + 1 - start,
                d: w.d,
                q: vec![w.q[i].clone(); i + 1 - start],
                k: w.k[start..=i].to_vec(),
                v: w.v[start..=i].to_vec(),
            };
            wt.q.truncate(wt.n);
            let expect = sdpa_f64(&wt);
            for (a, b) in masked[i].iter().zip(&expect[0]) {
                assert!((a - b).abs() < 1e-6, "row {i}");
            }
        }
        // Wide windows reduce to plain causal.
        assert_eq!(
            sdpa_f64_masked(&w, &Mask::window(w.n)),
            sdpa_f64_masked(&w, &Mask::Causal),
            "window(N) ≡ causal"
        );
        assert_eq!(
            sdpa_online_f32_masked(&w, &Mask::window(w.n)),
            sdpa_online_f32_masked(&w, &Mask::Causal)
        );
    }

    #[test]
    fn masked_references_agree_with_f64_oracle() {
        let w = Workload::random(12, 6, 77);
        for mask in [Mask::Causal, Mask::ragged(5), Mask::Full, Mask::window(4)] {
            let gold = sdpa_f64_masked(&w, &mask);
            assert_close(
                &sdpa_f32_scaled_masked(&w, &mask),
                &gold,
                3e-5,
                &format!("scaled masked {}", mask.name()),
            );
            assert_close(
                &sdpa_f32_unscaled_masked(&w, &mask),
                &gold,
                3e-5,
                &format!("unscaled masked {}", mask.name()),
            );
            assert_close(
                &sdpa_online_f32_masked(&w, &mask),
                &gold,
                3e-5,
                &format!("online masked {}", mask.name()),
            );
        }
    }

    #[test]
    fn full_mask_reduces_to_unmasked_references() {
        let w = Workload::random(8, 4, 31);
        assert_eq!(sdpa_f64_masked(&w, &Mask::Full), sdpa_f64(&w));
        assert_eq!(sdpa_online_f32_masked(&w, &Mask::Full), sdpa_online_f32(&w));
        assert_eq!(
            sdpa_f32_scaled_masked(&w, &Mask::Full),
            sdpa_f32_scaled(&w)
        );
    }

    #[test]
    fn ragged_padding_rows_repeat_the_last_valid_visibility() {
        // Padding rows (i ≥ len) attend the full valid prefix; with
        // row-dependent q they differ per row but use the same keys.
        let w = Workload::random(6, 4, 91);
        let masked = sdpa_f64_masked(&w, &Mask::ragged(3));
        let trunc = sdpa_f64_causal(&w.prefix(3));
        for i in 0..3 {
            for (a, b) in masked[i].iter().zip(&trunc[i]) {
                assert!((a - b).abs() < 1e-6, "valid row {i}");
            }
        }
        // Padding rows: each equals full (unmasked) attention of its
        // own query over exactly the valid prefix's keys/values.
        for i in 3..6 {
            let mut wp = w.prefix(3);
            wp.q = vec![w.q[i].clone(); 3];
            let expect = sdpa_f64(&wp);
            for (a, b) in masked[i].iter().zip(&expect[0]) {
                assert!((a - b).abs() < 1e-6, "padding row {i}");
            }
        }
    }
}
