//! Figure 2 — the naive SDPA algorithm mapped to the abstract hardware.
//!
//! ```text
//!            ┌──────────── score front-end ───────────┐
//! Q rows → Repeat(N) ─ Zip(dot·1/√d) ─ Map(exp) ─ Broadcast
//! Kᵀ cols ───────────────↗                            │    │
//!                                                     │    └→ Reduce(N, 0, +) → Repeat(N) ┐
//!                                       e_bypass (LONG FIFO, depth N+2)                   │
//!                                                     └───────────────→ Zip(÷) ←──────────┘
//!                                                                        │ p_ij
//! V rows (cyclic) ────────────────────────────────────────→ Zip(p·v⃗) ←──┘
//!                                                             │
//!                                              MemReduce(N, 0⃗, +) → o⃗_i → Sink
//! ```
//!
//! The `Reduce` emits the row denominator only after folding all N
//! exponentials, so the divider's other operand must buffer ~N elements:
//! with short FIFOs everywhere, `e_bypass` needs depth **N+2** (N+1
//! steady-state occupancy + 1 slot so the producer never stalls under
//! two-phase commit). The compile-time depth analysis derives exactly
//! this bound ([`DepthPolicy::Inferred`]); shallower bypass depths wedge
//! the broadcast and deadlock the graph — the experiment `fig2` sweeps
//! exactly this.

use super::workload::{Mask, Workload};
use super::{pv_tail, score_frontend_masked, BuiltAttention, DepthPolicy, FifoPlan};
use crate::sim::{Elem, GraphBuilder};
use crate::Result;

/// Build the Figure-2 graph. The long FIFO (`e_bypass`) takes
/// `plan.long`; everything else takes `plan.short`.
pub fn build(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_with_policy(w, DepthPolicy::Explicit(*plan))
}

/// Figure-2 graph under a depth policy (`Inferred` derives N+2).
pub fn build_with_policy(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_with_delays_policy(w, policy, 1, 0)
}

/// Figure-2 graph with an explicit pipeline latency on the `exp` unit.
///
/// Note: `exp` sits on the *common* path (before the broadcast), so its
/// latency delays both divergent paths equally and does **not** change
/// the required bypass depth — one of the two findings of
/// `experiments::ablation`.
pub fn build_with_exp_latency(
    w: &Workload,
    plan: &FifoPlan,
    exp_latency: u64,
) -> Result<BuiltAttention> {
    build_with_delays(w, plan, exp_latency, 0)
}

/// Figure-2 graph with both ablation knobs: `exp_latency` on the common
/// path and `sigma_delay` extra pipeline stages on the *reduction*
/// (divergent) path between `Reduce` and `Repeat` — modelling, e.g., a
/// deeper normalization unit. Every cycle of divergent-path latency
/// costs one more `e_bypass` slot; common-path latency costs none.
pub fn build_with_delays(
    w: &Workload,
    plan: &FifoPlan,
    exp_latency: u64,
    sigma_delay: u64,
) -> Result<BuiltAttention> {
    build_with_delays_policy(w, DepthPolicy::Explicit(*plan), exp_latency, sigma_delay)
}

/// The ablation builder under an arbitrary depth policy. With
/// `DepthPolicy::Inferred` the compile stage must reproduce the
/// N+2+`sigma_delay` bound the empirical bisection finds.
pub fn build_with_delays_policy(
    w: &Workload,
    policy: DepthPolicy,
    exp_latency: u64,
    sigma_delay: u64,
) -> Result<BuiltAttention> {
    build_masked_impl(w, policy, exp_latency, sigma_delay, &Mask::Full)
}

/// Figure-2 graph with an in-stream [`Mask`]: masked scores enter the
/// exponential as −∞ ⇒ e = 0, dropping out of the row sum and the PV
/// contraction while still occupying their stream slot — so the bypass
/// depth bound stays N+2 (see [`super::causal`]).
pub fn build_masked_with_policy(
    w: &Workload,
    mask: &Mask,
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    build_masked_impl(w, policy, 1, 0, mask)
}

fn build_masked_impl(
    w: &Workload,
    policy: DepthPolicy,
    exp_latency: u64,
    sigma_delay: u64,
    mask: &Mask,
) -> Result<BuiltAttention> {
    let n = w.n;
    let mut g = GraphBuilder::new();
    let mut sc = g.root();

    let s = score_frontend_masked(&mut sc, w, mask)?;

    // Softmax numerator: e_ij = exp(s_ij), no max subtraction (§3).
    let e = sc.map_latency("exp", s, exp_latency, |x| Elem::Scalar(x.scalar().exp()))?;

    // Divergent paths: row-sum reduction vs element bypass.
    let [e_sum, e_bypass] = sc.broadcast("bc_e", e, ["e_sum", "e_bypass"])?;

    let mut sigma = sc.reduce("row_sum", e_sum, n, 0.0, |a, b| a + b)?;
    if sigma_delay > 0 {
        // Extra pipeline stages on the reduction path only.
        sigma = sc.map_latency("sigma_delay", sigma, sigma_delay, |x| x.clone())?;
    }
    let sigma_rep = sc.repeat("rep_sigma", sigma, n)?;

    // p_ij = e_ij / σ_i.
    let p = sc.zip("div", [e_bypass, sigma_rep], |xs| {
        Elem::Scalar(xs[0].scalar() / xs[1].scalar())
    })?;

    let out = pv_tail(&mut sc, w, p)?;
    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n,
        d: w.d,
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f32_unscaled, sdpa_f64};
    use super::super::{FifoPlan, Variant};
    use super::*;
    use crate::sim::metrics::is_full_throughput;
    use crate::sim::{Capacity, RunOutcome};

    #[test]
    fn matches_reference_numerics() {
        let w = Workload::random(12, 8, 100);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(&got, &sdpa_f32_unscaled(&w), 1e-5, "naive vs f32 ref");
        assert_close(&got, &sdpa_f64(&w), 1e-4, "naive vs f64 ref");
    }

    #[test]
    fn paper_config_achieves_full_throughput() {
        let w = Workload::random(16, 4, 3);
        let mut finite = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, s_finite) = finite.run().unwrap();
        let mut base = build(&w, &FifoPlan::unbounded()).unwrap();
        let (_, s_base) = base.run().unwrap();
        assert!(
            is_full_throughput(&s_finite, &s_base),
            "finite {} vs baseline {}",
            s_finite.cycles,
            s_base.cycles
        );
    }

    #[test]
    fn bypass_occupancy_is_order_n() {
        let w = Workload::random(16, 4, 4);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        let peak = summary.peak_elems("e_bypass").unwrap();
        assert!(
            peak >= w.n && peak <= w.n + 2,
            "peak {} for N={}",
            peak,
            w.n
        );
    }

    #[test]
    fn short_bypass_deadlocks() {
        let w = Workload::random(16, 4, 5);
        let mut built = build(&w, &FifoPlan::with_long_depth(2)).unwrap();
        let summary = built.run_outcome();
        assert!(
            matches!(summary.outcome, RunOutcome::Deadlock { .. }),
            "expected deadlock, got {:?}",
            summary.outcome
        );
    }

    #[test]
    fn inferred_depths_match_paper_plan() {
        let w = Workload::random(16, 4, 6);
        let built = build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        let rec = built
            .engine
            .depth_report()
            .iter()
            .find(|c| c.name == "e_bypass")
            .unwrap();
        assert!(rec.is_long);
        assert_eq!(rec.inferred, w.n + 2);
        assert_eq!(rec.capacity, Capacity::Bounded(w.n + 2));
    }

    #[test]
    fn variant_dispatch_builds_naive() {
        let w = Workload::random(8, 4, 6);
        let mut built = Variant::Naive.build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_eq!(got.len(), 8);
        assert_eq!(got[0].len(), 4);
    }

    #[test]
    fn causal_mask_matches_masked_reference_and_keeps_bypass_bound() {
        use super::super::reference::sdpa_f32_unscaled_masked;
        let w = Workload::random(12, 4, 61);
        let built = build_masked_with_policy(&w, &Mask::Causal, DepthPolicy::Inferred).unwrap();
        // In-stream masking does not shorten the stream: the bypass is
        // still inferred at N+2.
        let rec = built
            .engine
            .depth_report()
            .iter()
            .find(|c| c.name == "e_bypass")
            .unwrap()
            .clone();
        assert!(rec.is_long);
        assert_eq!(rec.inferred, w.n + 2);
        let mut built = built;
        let (got, _) = built.run().unwrap();
        assert_close(
            &got,
            &sdpa_f32_unscaled_masked(&w, &Mask::Causal),
            1e-5,
            "causal naive vs masked ref",
        );
    }
}
