//! Figure 2 — the naive SDPA algorithm mapped to the abstract hardware.
//!
//! ```text
//!            ┌──────────── score front-end ───────────┐
//! Q rows → Repeat(N) ─ Zip(dot·1/√d) ─ Map(exp) ─ Broadcast
//! Kᵀ cols ───────────────↗                            │    │
//!                                                     │    └→ Reduce(N, 0, +) → Repeat(N) ┐
//!                                       e_bypass (LONG FIFO, depth N+2)                   │
//!                                                     └───────────────→ Zip(÷) ←──────────┘
//!                                                                        │ p_ij
//! V rows (cyclic) ────────────────────────────────────────→ Zip(p·v⃗) ←──┘
//!                                                             │
//!                                              MemReduce(N, 0⃗, +) → o⃗_i → Sink
//! ```
//!
//! The `Reduce` emits the row denominator only after folding all N
//! exponentials, so the divider's other operand must buffer ~N elements:
//! with short FIFOs everywhere, `e_bypass` needs depth **N+2** (N+1
//! steady-state occupancy + 1 slot so the producer never stalls under
//! two-phase commit). Shallower bypass depths wedge the broadcast and
//! deadlock the graph — the experiment `fig2` sweeps exactly this.

use super::{build_pv_tail, build_score_frontend, BuiltAttention, FifoPlan};
use crate::sim::{Elem, GraphBuilder};
use crate::Result;
use super::workload::Workload;

/// Build the Figure-2 graph. The long FIFO (`e_bypass`) takes
/// `plan.long`; everything else takes `plan.short`.
pub fn build(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_with_exp_latency(w, plan, 1)
}

/// Figure-2 graph with an explicit pipeline latency on the `exp` unit.
///
/// Note: `exp` sits on the *common* path (before the broadcast), so its
/// latency delays both divergent paths equally and does **not** change
/// the required bypass depth — one of the two findings of
/// `experiments::ablation`.
pub fn build_with_exp_latency(
    w: &Workload,
    plan: &FifoPlan,
    exp_latency: u64,
) -> Result<BuiltAttention> {
    build_with_delays(w, plan, exp_latency, 0)
}

/// Figure-2 graph with both ablation knobs: `exp_latency` on the common
/// path and `sigma_delay` extra pipeline stages on the *reduction*
/// (divergent) path between `Reduce` and `Repeat` — modelling, e.g., a
/// deeper normalization unit. Every cycle of divergent-path latency
/// costs one more `e_bypass` slot; common-path latency costs none.
pub fn build_with_delays(
    w: &Workload,
    plan: &FifoPlan,
    exp_latency: u64,
    sigma_delay: u64,
) -> Result<BuiltAttention> {
    let n = w.n;
    let mut g = GraphBuilder::new();

    let s = build_score_frontend(&mut g, w, plan)?;

    // Softmax numerator: e_ij = exp(s_ij), no max subtraction (§3).
    let e = g.channel("e", plan.short)?;
    g.map_latency("exp", s, e, exp_latency, |x| {
        Elem::Scalar(x.scalar().exp())
    })?;

    // Divergent paths: row-sum reduction vs element bypass.
    let e_sum = g.channel("e_sum", plan.short)?;
    let e_bypass = g.channel("e_bypass", plan.long)?;
    g.broadcast("bc_e", e, &[e_sum, e_bypass])?;

    let mut sigma = g.channel("sigma", plan.short)?;
    g.reduce("row_sum", e_sum, sigma, n, 0.0, |a, b| a + b)?;
    if sigma_delay > 0 {
        // Extra pipeline stages on the reduction path only.
        let delayed = g.channel("sigma_delayed", plan.short)?;
        g.map_latency("sigma_delay", sigma, delayed, sigma_delay, |x| x.clone())?;
        sigma = delayed;
    }
    let sigma_rep = g.channel("sigma_rep", plan.short)?;
    g.repeat("rep_sigma", sigma, sigma_rep, n)?;

    // p_ij = e_ij / σ_i.
    let p = g.channel("p", plan.short)?;
    g.zip("div", &[e_bypass, sigma_rep], p, |xs| {
        Elem::Scalar(xs[0].scalar() / xs[1].scalar())
    })?;

    let out = build_pv_tail(&mut g, w, plan, p)?;
    Ok(BuiltAttention {
        engine: g.build()?,
        out,
        n,
        d: w.d,
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f32_unscaled, sdpa_f64};
    use super::super::{FifoPlan, Variant};
    use super::*;
    use crate::sim::metrics::is_full_throughput;
    use crate::sim::RunOutcome;

    #[test]
    fn matches_reference_numerics() {
        let w = Workload::random(12, 8, 100);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(&got, &sdpa_f32_unscaled(&w), 1e-5, "naive vs f32 ref");
        assert_close(&got, &sdpa_f64(&w), 1e-4, "naive vs f64 ref");
    }

    #[test]
    fn paper_config_achieves_full_throughput() {
        let w = Workload::random(16, 4, 3);
        let mut finite = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, s_finite) = finite.run().unwrap();
        let mut base = build(&w, &FifoPlan::unbounded()).unwrap();
        let (_, s_base) = base.run().unwrap();
        assert!(
            is_full_throughput(&s_finite, &s_base),
            "finite {} vs baseline {}",
            s_finite.cycles,
            s_base.cycles
        );
    }

    #[test]
    fn bypass_occupancy_is_order_n() {
        let w = Workload::random(16, 4, 4);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        let peak = summary.peak_elems("e_bypass").unwrap();
        assert!(
            peak >= w.n && peak <= w.n + 2,
            "peak {} for N={}",
            peak,
            w.n
        );
    }

    #[test]
    fn short_bypass_deadlocks() {
        let w = Workload::random(16, 4, 5);
        let mut built = build(&w, &FifoPlan::with_long_depth(2)).unwrap();
        let summary = built.run_outcome();
        assert!(
            matches!(summary.outcome, RunOutcome::Deadlock { .. }),
            "expected deadlock, got {:?}",
            summary.outcome
        );
    }

    #[test]
    fn variant_dispatch_builds_naive() {
        let w = Workload::random(8, 4, 6);
        let mut built = Variant::Naive.build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_eq!(got.len(), 8);
        assert_eq!(got[0].len(), 4);
    }
}
