//! Figure 3(c) — the memory-free attention implementation (Eq. 3–6).
//!
//! The last O(N) FIFO of Figure 3(b) buffered scores while the row max
//! was reduced. Replacing the row-wise max with a **running** max turns
//! that reduction into an element-wise [`crate::sim::nodes::Scan`]: each
//! score immediately yields a rescale factor `Δ_ij = e^{m_{i(j-1)}−m_ij}`
//! and a numerator `e_ij = e^{s_ij−m_ij}` (Eq. 4). Downstream, running
//! sums absorb the rescale (Eq. 5):
//!
//! ```text
//! s ─ Scan(m running max → (Δ,e)) ─ Broadcast ─→ Scan(r ← r·Δ + e) ─ last-of-N → r_i ─┐
//!                                        └→ Zip(v⃗) → Scan(l⃗ ← l⃗·Δ + e·v⃗) ─ last-of-N → l⃗_i ─ Zip(l⃗/r) → o⃗_i
//! ```
//!
//! Every path is element-wise with matched latency (the r and l⃗ legs
//! differ by one hop, absorbed by a depth-2 FIFO), so **all FIFOs have
//! depth 2** and intermediate memory is O(1) — the paper's headline.
//! Accordingly the builder below names *no* channel and picks *no*
//! depth: the compile stage verifies the balance and sizes everything
//! at 2.

use super::workload::{Mask, Workload};
use super::{score_frontend_masked, v_source, BuiltAttention, DepthPolicy, FifoPlan};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Elem, GraphBuilder, Scope};
use crate::Result;

/// Build the Figure-3(c) graph. No long FIFOs exist, so `plan.long` is
/// unused; the paper's configuration is every FIFO at depth 2.
pub fn build(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_with_policy(w, DepthPolicy::Explicit(*plan))
}

/// Figure-3(c) graph under a depth policy (`Inferred` sizes every FIFO
/// at 2 — the compile-time proof of the O(1)-memory claim).
pub fn build_with_policy(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_masked_with_policy(w, &Mask::Full, policy)
}

/// Causal (autoregressive) extension: scores with j > i are masked to
/// −∞ *in the stream*, so the running-max scan sees `e = 0` for masked
/// positions and the output row i attends only to keys 0..=i. The
/// dataflow topology — and therefore the O(1)-memory, full-throughput
/// property — is unchanged; causality costs nothing on this machine.
pub fn build_causal(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_masked_with_policy(w, &Mask::Causal, DepthPolicy::Explicit(*plan))
}

/// Figure-3(c) graph with an arbitrary in-stream [`Mask`] (causal,
/// ragged, sliding-window). The mask rides a stateless source zipped
/// into the score front-end — not a counting `Map`, whose captured
/// counter would survive [`Engine::reset`](crate::sim::Engine::reset)
/// and corrupt replays (the decode replay property test guards this).
pub fn build_masked_with_policy(
    w: &Workload,
    mask: &Mask,
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let mut g = GraphBuilder::new();
    let mut sc = g.root();
    let out = build_into_masked(&mut sc, w, mask)?;
    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n: w.n,
        d: w.d,
    })
}

/// Build one memory-free pipeline into an existing scope — the
/// composition point for multi-head / sharded graphs (see
/// [`super::multihead`]). Returns the head's output sink.
pub fn build_into(sc: &mut Scope<'_>, w: &Workload) -> Result<SinkHandle> {
    build_into_masked(sc, w, &Mask::Full)
}

fn build_into_masked(sc: &mut Scope<'_>, w: &Workload, mask: &Mask) -> Result<SinkHandle> {
    let n = w.n;
    let d = w.d;

    let s = score_frontend_masked(sc, w, mask)?;

    // Running-max scan (Eq. 4). State = (m_prev, m); output = (Δ, e).
    // Inline `Pair` elements: this stream carries N² values (§Perf).
    let neg_inf = Elem::Pair(f32::NEG_INFINITY, f32::NEG_INFINITY);
    let de = sc.scan(
        "run_max",
        s,
        n,
        neg_inf,
        |st, x| {
            let (_, m_old) = st.pair();
            let m_new = m_old.max(x.scalar());
            Elem::Pair(m_old, m_new)
        },
        |st, x| {
            let (m_old, m_new) = st.pair();
            if m_new == f32::NEG_INFINITY {
                // Unseeded: every score so far this row was masked
                // (−∞), which only a non-prefix mask — Window — can
                // produce. −∞ − −∞ would be NaN; the correct update is
                // the exact identity Δ = e = 0, keeping r and l⃗ at 0
                // until the first visible score arrives (every mask
                // keeps the diagonal visible, so one always does).
                return Elem::Pair(0.0, 0.0);
            }
            // First visible element of a row: m_old = −∞ ⇒ Δ = 0
            // (nothing to rescale yet); e = e^{s−m} as usual.
            let delta = (m_old - m_new).exp();
            let e = (x.scalar() - m_new).exp();
            Elem::Pair(delta, e)
        },
    )?;

    let [de_r, de_l] = sc.broadcast("bc_de", de, ["de_r", "de_l"])?;

    // Running denominator (Eq. 5 scalar): r ← r·Δ + e, emitted each step.
    let r_run = sc.scan(
        "run_sum",
        de_r,
        n,
        Elem::Scalar(0.0),
        |st, x| {
            let (delta, e) = x.pair();
            Elem::Scalar(st.scalar() * delta + e)
        },
        |st, _| st.clone(),
    )?;
    let r = sc.last_of("last_r", r_run, n)?;

    // Running numerator (Eq. 5 vector): l⃗ ← l⃗·Δ + e·v⃗_j.
    let v_cols = v_source(sc, w)?;
    let dev = sc.zip("zip_v", [de_l, v_cols], |xs| {
        Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
    })?;
    let l_run = sc.scan(
        "run_out",
        dev,
        n,
        Elem::from(vec![0.0f32; d]),
        |st, x| {
            let (delta, e) = x.as_tuple()[0].pair();
            let v = x.as_tuple()[1].as_vector();
            Elem::from(
                st.as_vector()
                    .iter()
                    .zip(v)
                    .map(|(acc, vv)| acc * delta + e * vv)
                    .collect::<Vec<_>>(),
            )
        },
        |st, _| st.clone(),
    )?;
    let l = sc.last_of("last_l", l_run, n)?;

    // Final division (Eq. 6): o⃗_i = l⃗_iN / r_iN.
    let o = sc.zip("div", [l, r], |xs| {
        let r = xs[1].scalar();
        Elem::from(xs[0].as_vector().iter().map(|x| x / r).collect::<Vec<_>>())
    })?;
    sc.sink("sink_o", o, Some(n as u64))
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f64, sdpa_online_f32};
    use super::super::FifoPlan;
    use super::*;
    use crate::sim::metrics::is_full_throughput;
    use crate::sim::Capacity;

    #[test]
    fn matches_reference_numerics() {
        let w = Workload::random(12, 8, 400);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(&got, &sdpa_online_f32(&w), 1e-5, "memfree vs online ref");
        assert_close(&got, &sdpa_f64(&w), 1e-4, "memfree vs f64 ref");
    }

    #[test]
    fn survives_adversarial_magnitudes() {
        let w = Workload::large_magnitude(8, 4, 19, 200.0);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert!(got.iter().flatten().all(|x| x.is_finite()));
        assert_close(&got, &sdpa_f64(&w), 1e-4, "memfree adversarial");
    }

    #[test]
    fn all_short_fifos_achieve_full_throughput() {
        // The headline claim: depth-2 FIFOs everywhere, no slowdown.
        let w = Workload::random(16, 4, 33);
        let mut finite = build(&w, &FifoPlan::with_long_depth(2)).unwrap();
        let (_, s_finite) = finite.run().unwrap();
        let mut base = build(&w, &FifoPlan::unbounded()).unwrap();
        let (_, s_base) = base.run().unwrap();
        assert!(
            is_full_throughput(&s_finite, &s_base),
            "finite {} vs baseline {}",
            s_finite.cycles,
            s_base.cycles
        );
    }

    #[test]
    fn inference_finds_no_long_fifo() {
        // The compile-time twin of the O(1) claim: the analysis sizes
        // every channel at depth 2.
        let w = Workload::random(24, 4, 34);
        let built = build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        for c in built.engine.depth_report() {
            assert!(!c.is_long, "channel '{}' flagged long", c.name);
            assert_eq!(c.capacity, Capacity::Bounded(2), "channel '{}'", c.name);
        }
    }

    #[test]
    fn peak_occupancy_is_constant() {
        let w = Workload::random(24, 4, 34);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, stats) in &summary.channel_stats {
            assert!(
                stats.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {} elements — not O(1)",
                stats.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn causal_matches_causal_reference() {
        use super::super::reference::sdpa_f64_causal;
        let w = Workload::random(16, 8, 55);
        let mut built = build_causal(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, summary) = built.run().unwrap();
        assert_close(&got, &sdpa_f64_causal(&w), 1e-4, "causal memfree");
        // Causality does not change the memory story: still O(1).
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "causal: channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn causal_is_full_throughput_too() {
        let w = Workload::random(16, 4, 56);
        let mut finite = build_causal(&w, &FifoPlan::with_long_depth(2)).unwrap();
        let (_, fs) = finite.run().unwrap();
        let mut base = build_causal(&w, &FifoPlan::unbounded()).unwrap();
        let (_, bs) = base.run().unwrap();
        assert!(is_full_throughput(&fs, &bs));
    }

    #[test]
    fn ragged_mask_matches_masked_online_reference() {
        use super::super::reference::sdpa_online_f32_masked;
        let w = Workload::random(10, 4, 58);
        let mask = Mask::ragged(6);
        let mut built =
            build_masked_with_policy(&w, &mask, DepthPolicy::Inferred).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(
            &got,
            &sdpa_online_f32_masked(&w, &mask),
            1e-6,
            "ragged memfree vs masked online ref",
        );
    }

    #[test]
    fn causal_reset_replay_is_bit_identical() {
        // Regression: the causal mask used to live in a counting Map
        // whose captured counter survived Engine::reset, so a replay
        // masked the wrong positions. The mask now rides a stateless
        // source.
        let w = Workload::random(8, 4, 59);
        let mut built = build_causal(&w, &FifoPlan::paper(w.n)).unwrap();
        let (first, s1) = built.run().unwrap();
        built.engine.reset();
        let (second, s2) = built.run().unwrap();
        assert_eq!(first, second, "replay must reproduce outputs bitwise");
        assert_eq!(s1.cycles, s2.cycles);
        assert_eq!(s1.node_fires, s2.node_fires);
    }

    #[test]
    fn output_rows_arrive_every_n_cycles() {
        let w = Workload::random(16, 4, 35);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        built.run().unwrap();
        // Steady state: one o⃗_i per N cycles (II=1 over N² elements).
        let gaps = built.out.arrival_gaps(8).unwrap();
        assert_eq!(gaps, (w.n as u64, w.n as u64));
    }
}
