//! FLASH-D — the memory-free recurrence with the softmax division
//! hidden inside the exponential (PAPERS.md: "FLASH-D: FlashAttention
//! with Hidden Softmax Division").
//!
//! Figure 3(c) still ends in a divider: `o⃗_i = l⃗_iN / r_iN` (Eq. 6).
//! FLASH-D removes it by carrying a running **log-sum-exp** `t` instead
//! of the `(m, r)` pair and emitting *already-normalized* weights:
//!
//! ```text
//! t_j = max(t_{j-1}, s_j) + ln(1 + e^{−|t_{j-1} − s_j|})   (log-sum-exp)
//! w_j = e^{s_j − t_j}                                      (hidden division)
//! o⃗_j = o⃗_{j-1} + w_j · (v⃗_j − o⃗_{j-1})                    (exact EMA)
//! ```
//!
//! By induction `o⃗_j = Σ_{k≤j} e^{s_k} v⃗_k / Σ_{k≤j} e^{s_k}` — the
//! softmax-weighted output is normalized at *every* step, so the row's
//! last EMA state **is** the answer and the graph has **no divider node
//! at all** (only max, abs, exp, ln_1p, add, mul). The dataflow maps
//! onto two element-wise scans:
//!
//! ```text
//! s ─ Scan(t running lse → w) ─┐
//!                              Zip(w, v⃗) → Scan(o⃗ ← o⃗ + w(v⃗−o⃗)) ─ last-of-N → o⃗_i
//! v⃗_j ─────────────────────────┘
//! ```
//!
//! That is *fewer* nodes than even the memory-free graph (no broadcast,
//! no separate denominator scan, no last-of-r, no divide zip) — the
//! codesign study ([`crate::experiments::codesign`]) quantifies the
//! node/FIFO-slot/cycle savings vs the reordered variant. Every path is
//! element-wise, so all FIFOs stay at depth 2 and intermediate memory
//! is O(1), same as [`super::memfree`].
//!
//! Masked streams fall out of IEEE arithmetic plus two guards:
//!
//! * `s = −∞` (masked slot, `t` seeded): `max(t, s) = t`,
//!   `e^{−|t−s|} = e^{−∞} = 0`, `ln_1p(0) = 0` ⇒ `t` unchanged; the
//!   weight guard emits `w = 0` ⇒ `o⃗` unchanged — an exact identity
//!   update, so in-stream masking perturbs nothing.
//! * `t = s = −∞` (unseeded: every score so far masked, which only a
//!   front-masking [`Mask::Window`] produces): `max` is −∞ and the
//!   recurrence would form `−∞ + ln_1p(…)` on the next visible score;
//!   the lse guard pins `t = −∞` until a visible score `s` arrives,
//!   which then yields `t = s` exactly and `w = e^0 = 1` ⇒ `o⃗ = v⃗` —
//!   the correct first-element state.

use super::workload::{Mask, Workload};
use super::{score_frontend_masked, v_source, BuiltAttention, DepthPolicy, FifoPlan};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Elem, GraphBuilder, Scope};
use crate::Result;

/// One FLASH-D log-sum-exp update: fold score `s` into the running
/// lse `t`. Shared verbatim by the prefill scan, the decode-step scan
/// ([`super::decode`]), and the sequential reference
/// ([`super::reference::sdpa_flashd_f32_masked`]) so all three execute
/// the same f32 operations in the same order.
#[inline]
pub(crate) fn lse_fold(t: f32, s: f32) -> f32 {
    let m = t.max(s);
    if m == f32::NEG_INFINITY {
        // Unseeded and masked: stay unseeded (−∞ + ln_1p(…) = NaN).
        f32::NEG_INFINITY
    } else {
        m + (-(t - s).abs()).exp().ln_1p()
    }
}

/// The hidden-division weight `w = e^{s − t_new}` (0 for a masked slot
/// — `e^{−∞ − −∞}` would be NaN, and a masked score must contribute
/// nothing).
#[inline]
pub(crate) fn hidden_weight(s: f32, t_new: f32) -> f32 {
    if s == f32::NEG_INFINITY {
        0.0
    } else {
        (s - t_new).exp()
    }
}

/// Build the FLASH-D graph. No long FIFOs exist, so `plan.long` is
/// unused; the configuration is every FIFO at depth 2.
pub fn build(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_with_policy(w, DepthPolicy::Explicit(*plan))
}

/// FLASH-D graph under a depth policy (`Inferred` sizes every FIFO at
/// 2 — the same compile-time O(1)-memory proof as the memory-free
/// graph, over a strictly smaller node count).
pub fn build_with_policy(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_masked_with_policy(w, &Mask::Full, policy)
}

/// Causal FLASH-D: scores with j > i are masked to −∞ in the stream;
/// the lse/weight guards turn masked slots into exact identity updates.
pub fn build_causal(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_masked_with_policy(w, &Mask::Causal, DepthPolicy::Explicit(*plan))
}

/// FLASH-D with an arbitrary in-stream [`Mask`] (causal, ragged,
/// sliding-window). The mask rides the same stateless source as every
/// other masked graph, so `Engine::reset` replays are bit-identical.
pub fn build_masked_with_policy(
    w: &Workload,
    mask: &Mask,
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let mut g = GraphBuilder::new();
    let mut sc = g.root();
    let out = build_into_masked(&mut sc, w, mask)?;
    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n: w.n,
        d: w.d,
    })
}

/// Build one FLASH-D pipeline into an existing scope — the composition
/// point for multi-head graphs. Returns the head's output sink.
pub fn build_into(sc: &mut Scope<'_>, w: &Workload) -> Result<SinkHandle> {
    build_into_masked(sc, w, &Mask::Full)
}

fn build_into_masked(sc: &mut Scope<'_>, w: &Workload, mask: &Mask) -> Result<SinkHandle> {
    let n = w.n;
    let d = w.d;

    let s = score_frontend_masked(sc, w, mask)?;

    // Running log-sum-exp scan. State = t; output = the normalized
    // weight w = e^{s − t_new} — the division, hidden in the exponent.
    let wgt = sc.scan(
        "run_lse",
        s,
        n,
        Elem::Scalar(f32::NEG_INFINITY),
        |st, x| Elem::Scalar(lse_fold(st.scalar(), x.scalar())),
        |st, x| Elem::Scalar(hidden_weight(x.scalar(), st.scalar())),
    )?;

    // Exact EMA: o⃗ ← o⃗ + w·(v⃗ − o⃗), normalized at every step — the
    // row's last state is the finished output row, no divide needed.
    let v_cols = v_source(sc, w)?;
    let wv = sc.zip("zip_wv", [wgt, v_cols], |xs| {
        Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
    })?;
    let o_run = sc.scan(
        "run_ema",
        wv,
        n,
        Elem::from(vec![0.0f32; d]),
        |st, x| {
            let wgt = x.as_tuple()[0].scalar();
            let v = x.as_tuple()[1].as_vector();
            Elem::from(
                st.as_vector()
                    .iter()
                    .zip(v)
                    .map(|(o, vv)| o + wgt * (vv - o))
                    .collect::<Vec<_>>(),
            )
        },
        |st, _| st.clone(),
    )?;
    let o = sc.last_of("last_o", o_run, n)?;
    sc.sink("sink_o", o, Some(n as u64))
}

#[cfg(test)]
mod tests {
    use super::super::reference::{
        assert_close, sdpa_f64, sdpa_f64_masked, sdpa_flashd_f32, sdpa_flashd_f32_masked,
    };
    use super::super::FifoPlan;
    use super::*;
    use crate::sim::metrics::is_full_throughput;
    use crate::sim::Capacity;

    #[test]
    fn matches_reference_numerics() {
        let w = Workload::random(12, 8, 0xF1A5);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(&got, &sdpa_flashd_f32(&w), 1e-6, "flashd vs sequential ref");
        assert_close(&got, &sdpa_f64(&w), 1e-4, "flashd vs f64 ref");
    }

    #[test]
    fn survives_adversarial_magnitudes() {
        // w = e^{s − t} ≤ 1 always and o⃗ is a convex combination at
        // every step — nothing can overflow.
        let w = Workload::large_magnitude(8, 4, 0xF1A6, 200.0);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        assert!(got.iter().flatten().all(|x| x.is_finite()));
        assert_close(&got, &sdpa_f64(&w), 1e-4, "flashd adversarial");
    }

    #[test]
    fn no_division_node_exists() {
        // The headline: the divider is gone from the pipeline, not
        // merely relocated. No node in the graph is a divide.
        let w = Workload::random(8, 4, 0xF1A7);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, _) in &summary.node_fires {
            assert_ne!(name, "div", "FLASH-D must not contain a divider node");
        }
    }

    #[test]
    fn all_short_fifos_achieve_full_throughput() {
        let w = Workload::random(16, 4, 0xF1A8);
        let mut finite = build(&w, &FifoPlan::with_long_depth(2)).unwrap();
        let (_, s_finite) = finite.run().unwrap();
        let mut base = build(&w, &FifoPlan::unbounded()).unwrap();
        let (_, s_base) = base.run().unwrap();
        assert!(
            is_full_throughput(&s_finite, &s_base),
            "finite {} vs baseline {}",
            s_finite.cycles,
            s_base.cycles
        );
    }

    #[test]
    fn inference_finds_no_long_fifo() {
        let w = Workload::random(24, 4, 0xF1A9);
        let built = build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        for c in built.engine.depth_report() {
            assert!(!c.is_long, "channel '{}' flagged long", c.name);
            assert_eq!(c.capacity, Capacity::Bounded(2), "channel '{}'", c.name);
        }
    }

    #[test]
    fn strictly_fewer_nodes_than_memfree_and_reordered() {
        // FLASH-D removes not just the divider but the broadcast, the
        // denominator scan, and its last-of — the codesign claim,
        // asserted here at the graph level and study-wide in
        // `experiments::codesign`.
        let w = Workload::random(8, 4, 0xF1AA);
        let flashd = build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        let memfree = super::super::memfree::build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        let reordered =
            super::super::reordered::build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        assert!(flashd.engine.node_count() < memfree.engine.node_count());
        assert!(flashd.engine.node_count() < reordered.engine.node_count());
    }

    #[test]
    fn peak_occupancy_is_constant() {
        let w = Workload::random(24, 4, 0xF1AB);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, stats) in &summary.channel_stats {
            assert!(
                stats.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {} elements — not O(1)",
                stats.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn causal_matches_causal_reference() {
        let w = Workload::random(16, 8, 0xF1AC);
        let mut built = build_causal(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, summary) = built.run().unwrap();
        assert_close(
            &got,
            &sdpa_flashd_f32_masked(&w, &Mask::Causal),
            1e-6,
            "causal flashd vs sequential ref",
        );
        assert_close(
            &got,
            &sdpa_f64_masked(&w, &Mask::Causal),
            1e-4,
            "causal flashd vs f64",
        );
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "causal: channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn window_mask_exercises_the_unseeded_guard() {
        // Window masks blank the *front* of a row: the lse guard must
        // hold t = −∞ across the leading masked run, then seed t = s
        // exactly (w = 1, o⃗ = v⃗) at the first visible score.
        let w = Workload::random(10, 4, 0xF1AD);
        let mask = Mask::window(3);
        let mut built = build_masked_with_policy(&w, &mask, DepthPolicy::Inferred).unwrap();
        let (got, _) = built.run().unwrap();
        assert!(got.iter().flatten().all(|x| x.is_finite()), "no NaN leaked");
        assert_close(
            &got,
            &sdpa_flashd_f32_masked(&w, &mask),
            1e-6,
            "windowed flashd vs sequential ref",
        );
        assert_close(
            &got,
            &sdpa_f64_masked(&w, &mask),
            1e-4,
            "windowed flashd vs f64",
        );
    }

    #[test]
    fn ragged_mask_matches_masked_reference() {
        let w = Workload::random(10, 4, 0xF1AE);
        let mask = Mask::ragged(6);
        let mut built = build_masked_with_policy(&w, &mask, DepthPolicy::Inferred).unwrap();
        let (got, _) = built.run().unwrap();
        assert_close(
            &got,
            &sdpa_flashd_f32_masked(&w, &mask),
            1e-6,
            "ragged flashd vs masked ref",
        );
    }

    #[test]
    fn causal_reset_replay_is_bit_identical() {
        let w = Workload::random(8, 4, 0xF1AF);
        let mut built = build_causal(&w, &FifoPlan::paper(w.n)).unwrap();
        let (first, s1) = built.run().unwrap();
        built.engine.reset();
        let (second, s2) = built.run().unwrap();
        assert_eq!(first, second, "replay must reproduce outputs bitwise");
        assert_eq!(s1.cycles, s2.cycles);
        assert_eq!(s1.node_fires, s2.node_fires);
    }

    #[test]
    fn output_rows_arrive_every_n_cycles() {
        let w = Workload::random(16, 4, 0xF1B0);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        built.run().unwrap();
        let gaps = built.out.arrival_gaps(8).unwrap();
        assert_eq!(gaps, (w.n as u64, w.n as u64));
    }
}
