//! Multi-head attention as *spatial* scale-out (extension).
//!
//! A streaming dataflow fabric scales attention throughput by placing
//! independent head pipelines side by side — the execution model's
//! answer to a GPU's grid dimension. This module composes `H`
//! memory-free (Figure 3c) pipelines in one engine by instantiating
//! [`super::memfree::build_into`] once per [`Scope`](crate::sim::Scope):
//! each head's nodes and channels are automatically namespaced
//! (`h{i}/...`), so summaries and deadlock reports stay readable and no
//! builder code ever concatenates name strings.
//!
//! Because the pipelines share no channels, the engine simulates true
//! spatial parallelism: total cycles stay ≈ N² + fill while *aggregate*
//! throughput grows to H scores/cycle, and intermediate memory grows
//! linearly in H but stays O(1) in N — the paper's claim, per head.

use super::reference::Matrix;
use super::workload::Workload;
use super::{cycle_budget, memfree, DepthPolicy, FifoPlan};
use crate::sim::nodes::SinkHandle;
use crate::sim::{GraphBuilder, RunSummary};
use crate::Result;

/// A built multi-head graph: one engine, `H` independent head pipelines.
pub struct BuiltMultiHead {
    /// The shared engine.
    pub engine: crate::sim::Engine,
    /// Per-head output sinks.
    pub heads: Vec<SinkHandle>,
    /// Sequence length.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
}

impl BuiltMultiHead {
    /// Run to completion, returning per-head outputs and the summary.
    pub fn run(&mut self) -> Result<(Vec<Matrix>, RunSummary)> {
        let summary = self.engine.run(cycle_budget(self.n))?;
        Ok((self.heads.iter().map(SinkHandle::rows).collect(), summary))
    }

    /// Aggregate scores processed per cycle for a completed run.
    pub fn scores_per_cycle(&self, summary: &RunSummary) -> f64 {
        (self.heads.len() * self.n * self.n) as f64 / summary.cycles as f64
    }
}

/// Build one memory-free pipeline per workload, all in one engine, with
/// the given FIFO plan.
pub fn build_memfree_heads(
    workloads: &[Workload],
    plan: &FifoPlan,
) -> Result<BuiltMultiHead> {
    build_memfree_heads_with_policy(workloads, DepthPolicy::Explicit(*plan))
}

/// Build one memory-free pipeline per workload under a depth policy.
/// Head `i` lives in scope `h{i}`.
pub fn build_memfree_heads_with_policy(
    workloads: &[Workload],
    policy: DepthPolicy,
) -> Result<BuiltMultiHead> {
    assert!(!workloads.is_empty());
    let n = workloads[0].n;
    let d = workloads[0].d;
    let mut g = GraphBuilder::new();
    let mut heads = Vec::with_capacity(workloads.len());
    for (h, w) in workloads.iter().enumerate() {
        assert_eq!((w.n, w.d), (n, d), "heads must share shape");
        let mut scope = g.scope(format!("h{h}"));
        heads.push(memfree::build_into(&mut scope, w)?);
    }
    Ok(BuiltMultiHead {
        engine: g.compile(policy)?,
        heads,
        n,
        d,
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f64};
    use super::*;

    fn heads(h: usize, n: usize, d: usize) -> Vec<Workload> {
        (0..h).map(|i| Workload::random(n, d, 900 + i as u64)).collect()
    }

    #[test]
    fn every_head_matches_its_reference() {
        let ws = heads(4, 12, 8);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(12)).unwrap();
        let (outs, _) = built.run().unwrap();
        assert_eq!(outs.len(), 4);
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "head output");
        }
    }

    #[test]
    fn inferred_heads_match_reference_too() {
        let ws = heads(2, 12, 4);
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        let (outs, summary) = built.run().unwrap();
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "inferred head output");
        }
        // Memory-free per head: the analysis finds no long FIFO anywhere.
        assert!(summary.depths.iter().all(|c| !c.is_long));
    }

    #[test]
    fn aggregate_throughput_scales_with_heads() {
        let n = 16;
        for h in [1usize, 2, 4, 8] {
            let ws = heads(h, n, 4);
            let mut built = build_memfree_heads(&ws, &FifoPlan::paper(n)).unwrap();
            let (_, summary) = built.run().unwrap();
            let spc = built.scores_per_cycle(&summary);
            // Spatial pipelines are independent: cycles stay ~N²+fill, so
            // aggregate throughput ≈ h scores/cycle.
            assert!(
                spc > 0.9 * h as f64 && spc <= h as f64,
                "h={h}: {spc} scores/cycle"
            );
        }
    }

    #[test]
    fn memory_stays_constant_per_head() {
        let ws = heads(4, 24, 4);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(24)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn heads_are_isolated_in_reports() {
        let ws = heads(2, 8, 4);
        let built = build_memfree_heads(&ws, &FifoPlan::paper(8)).unwrap();
        let names = built.engine.channel_names();
        assert!(names.iter().any(|n| n == "h0/run_max"));
        assert!(names.iter().any(|n| n == "h1/run_max"));
    }

    #[test]
    #[should_panic(expected = "heads must share shape")]
    fn mismatched_head_shapes_rejected() {
        let ws = vec![Workload::random(8, 4, 1), Workload::random(16, 4, 2)];
        let _ = build_memfree_heads(&ws, &FifoPlan::paper(8));
    }
}
