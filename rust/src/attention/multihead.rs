//! Multi-head attention as *spatial* scale-out (extension).
//!
//! A streaming dataflow fabric scales attention throughput by placing
//! independent head pipelines side by side — the execution model's
//! answer to a GPU's grid dimension. This module instantiates `H`
//! memory-free (Figure 3c) pipelines in one engine, each with its own
//! sources and sink, and measures aggregate throughput.
//!
//! Because the pipelines share no channels, the engine simulates true
//! spatial parallelism: total cycles stay ≈ N² + fill while *aggregate*
//! throughput grows to H scores/cycle, and intermediate memory grows
//! linearly in H but stays O(1) in N — the paper's claim, per head.

use super::reference::Matrix;
use super::workload::{dot, Workload};
use super::{BuiltAttention, FifoPlan};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Elem, GraphBuilder, RunSummary};
use crate::Result;

/// A built multi-head graph: one engine, `H` independent head pipelines.
pub struct BuiltMultiHead {
    /// The shared engine.
    pub engine: crate::sim::Engine,
    /// Per-head output sinks.
    pub heads: Vec<SinkHandle>,
    /// Sequence length.
    pub n: usize,
    /// Head dimension.
    pub d: usize,
}

impl BuiltMultiHead {
    /// Run to completion, returning per-head outputs and the summary.
    pub fn run(&mut self) -> Result<(Vec<Matrix>, RunSummary)> {
        let n = self.n as u64;
        let summary = self.engine.run(10 * n * n + 20 * n + 500)?;
        Ok((self.heads.iter().map(SinkHandle::rows).collect(), summary))
    }

    /// Aggregate scores processed per cycle for a completed run.
    pub fn scores_per_cycle(&self, summary: &RunSummary) -> f64 {
        (self.heads.len() * self.n * self.n) as f64 / summary.cycles as f64
    }
}

/// Build one memory-free pipeline per workload, all in one engine.
///
/// Each head gets uniquely prefixed node/channel names (`h{i}/...`), so
/// summaries and deadlock reports stay readable.
pub fn build_memfree_heads(
    workloads: &[Workload],
    plan: &FifoPlan,
) -> Result<BuiltMultiHead> {
    assert!(!workloads.is_empty());
    let n = workloads[0].n;
    let d = workloads[0].d;
    let mut g = GraphBuilder::new();
    let mut heads = Vec::with_capacity(workloads.len());
    for (h, w) in workloads.iter().enumerate() {
        assert_eq!((w.n, w.d), (n, d), "heads must share shape");
        heads.push(build_one_head(&mut g, w, plan, &format!("h{h}/"))?);
    }
    Ok(BuiltMultiHead {
        engine: g.build()?,
        heads,
        n,
        d,
    })
}

/// One prefixed memory-free pipeline (same topology as
/// [`super::memfree::build`]).
fn build_one_head(
    g: &mut GraphBuilder,
    w: &Workload,
    plan: &FifoPlan,
    p: &str,
) -> Result<SinkHandle> {
    let n = w.n;
    let d = w.d;
    let total = (n * n) as u64;

    // Score front-end.
    let q_rows = g.channel(format!("{p}q_rows"), plan.short)?;
    let q_rep = g.channel(format!("{p}q_rep"), plan.short)?;
    let k_cols = g.channel(format!("{p}k_cols"), plan.short)?;
    let s = g.channel(format!("{p}s"), plan.short)?;
    let q: Vec<Elem> = w.q.iter().map(|r| Elem::vector(r)).collect();
    g.source_vec(&format!("{p}src_q"), q_rows, q)?;
    g.repeat(&format!("{p}rep_q"), q_rows, q_rep, n)?;
    let k: Vec<Elem> = w.k.iter().map(|r| Elem::vector(r)).collect();
    g.source_gen(&format!("{p}src_k"), k_cols, total, move |i| {
        k[(i % n as u64) as usize].clone()
    })?;
    let scale = w.scale();
    g.zip(&format!("{p}qk_dot"), &[q_rep, k_cols], s, move |xs| {
        Elem::Scalar(dot(xs[0].as_vector(), xs[1].as_vector()) * scale)
    })?;

    // Running-max scan → (Δ, e).
    let de = g.channel(format!("{p}de"), plan.short)?;
    g.scan(
        &format!("{p}run_max"),
        s,
        de,
        n,
        Elem::Pair(f32::NEG_INFINITY, f32::NEG_INFINITY),
        |st, x| {
            let (_, m_old) = st.pair();
            Elem::Pair(m_old, m_old.max(x.scalar()))
        },
        |st, x| {
            let (m_old, m_new) = st.pair();
            Elem::Pair((m_old - m_new).exp(), (x.scalar() - m_new).exp())
        },
    )?;
    let de_r = g.channel(format!("{p}de_r"), plan.short)?;
    let de_l = g.channel(format!("{p}de_l"), plan.short)?;
    g.broadcast(&format!("{p}bc_de"), de, &[de_r, de_l])?;

    let r_run = g.channel(format!("{p}r_run"), plan.short)?;
    g.scan(
        &format!("{p}run_sum"),
        de_r,
        r_run,
        n,
        Elem::Scalar(0.0),
        |st, x| {
            let (delta, e) = x.pair();
            Elem::Scalar(st.scalar() * delta + e)
        },
        |st, _| st.clone(),
    )?;
    let r = g.channel(format!("{p}r"), plan.short)?;
    g.last_of(&format!("{p}last_r"), r_run, r, n)?;

    let v_cols = g.channel(format!("{p}v_cols"), plan.short)?;
    let v: Vec<Elem> = w.v.iter().map(|row| Elem::vector(row)).collect();
    g.source_gen(&format!("{p}src_v"), v_cols, total, move |i| {
        v[(i % n as u64) as usize].clone()
    })?;
    let dev = g.channel(format!("{p}dev"), plan.short)?;
    g.zip(&format!("{p}zip_v"), &[de_l, v_cols], dev, |xs| {
        Elem::tuple(vec![xs[0].clone(), xs[1].clone()])
    })?;
    let l_run = g.channel(format!("{p}l_run"), plan.short)?;
    g.scan(
        &format!("{p}run_out"),
        dev,
        l_run,
        n,
        Elem::from(vec![0.0f32; d]),
        |st, x| {
            let (delta, e) = x.as_tuple()[0].pair();
            let v = x.as_tuple()[1].as_vector();
            Elem::from(
                st.as_vector()
                    .iter()
                    .zip(v)
                    .map(|(acc, vv)| acc * delta + e * vv)
                    .collect::<Vec<_>>(),
            )
        },
        |st, _| st.clone(),
    )?;
    let l = g.channel(format!("{p}l"), plan.short)?;
    g.last_of(&format!("{p}last_l"), l_run, l, n)?;

    let o = g.channel(format!("{p}o"), plan.short)?;
    g.zip(&format!("{p}div"), &[l, r], o, |xs| {
        let r = xs[1].scalar();
        Elem::from(xs[0].as_vector().iter().map(|x| x / r).collect::<Vec<_>>())
    })?;
    g.sink(&format!("{p}sink_o"), o, Some(n as u64))
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f64};
    use super::*;

    fn heads(h: usize, n: usize, d: usize) -> Vec<Workload> {
        (0..h).map(|i| Workload::random(n, d, 900 + i as u64)).collect()
    }

    #[test]
    fn every_head_matches_its_reference() {
        let ws = heads(4, 12, 8);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(12)).unwrap();
        let (outs, _) = built.run().unwrap();
        assert_eq!(outs.len(), 4);
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "head output");
        }
    }

    #[test]
    fn aggregate_throughput_scales_with_heads() {
        let n = 16;
        for h in [1usize, 2, 4, 8] {
            let ws = heads(h, n, 4);
            let mut built = build_memfree_heads(&ws, &FifoPlan::paper(n)).unwrap();
            let (_, summary) = built.run().unwrap();
            let spc = built.scores_per_cycle(&summary);
            // Spatial pipelines are independent: cycles stay ~N²+fill, so
            // aggregate throughput ≈ h scores/cycle.
            assert!(
                spc > 0.9 * h as f64 && spc <= h as f64,
                "h={h}: {spc} scores/cycle"
            );
        }
    }

    #[test]
    fn memory_stays_constant_per_head() {
        let ws = heads(4, 24, 4);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(24)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn heads_are_isolated_in_reports() {
        let ws = heads(2, 8, 4);
        let built = build_memfree_heads(&ws, &FifoPlan::paper(8)).unwrap();
        let names = built.engine.channel_names();
        assert!(names.iter().any(|n| n == "h0/de"));
        assert!(names.iter().any(|n| n == "h1/de"));
    }

    #[test]
    #[should_panic(expected = "heads must share shape")]
    fn mismatched_head_shapes_rejected() {
        let ws = vec![Workload::random(8, 4, 1), Workload::random(16, 4, 2)];
        let _ = build_memfree_heads(&ws, &FifoPlan::paper(8));
    }
}
