//! Multi-head / multi-lane attention as *spatial* scale-out.
//!
//! A streaming dataflow fabric scales attention throughput by placing
//! independent pipelines side by side — the execution model's answer to
//! a GPU's grid dimension. Two compositions live here, both built by
//! instantiating one pipeline per [`Scope`](crate::sim::Scope) so nodes
//! and channels are automatically namespaced and no builder code ever
//! concatenates name strings:
//!
//! * **Prefill heads** ([`build_memfree_heads`]): `H` memory-free
//!   (Figure 3c) pipelines, one per workload, sharing one engine. Heads
//!   may have *heterogeneous* shapes — each lane carries its own
//!   `(n, d)` and the aggregate throughput / cycle budget are computed
//!   from the actual per-lane workloads (a homogeneity `assert!` here
//!   used to panic the library on caller input; it is now an `Err`-free
//!   supported case, which the serving lane pool depends on).
//! * **Decode lanes** ([`build_decode_lanes`]): one decode *step* per
//!   active session (arbitrary per-lane cache length and head
//!   dimension), the engine one scheduling iteration of the
//!   continuous-batching server runs. Lanes share no channels, so each
//!   session's step computes bit-identically to the same step run alone
//!   — the property `tests/continuous_batching.rs` enforces.
//!
//! Because pipelines are independent, the engine simulates true spatial
//! parallelism: total cycles stay ≈ the slowest lane while *aggregate*
//! throughput grows with the lane count, and intermediate memory grows
//! linearly in lanes but stays O(1) in sequence length — the paper's
//! claim, per pipeline.

use super::decode::{build_step_rows_into, DecodeKind};
use super::reference::Matrix;
use super::workload::Workload;
use super::{cycle_budget, memfree, DepthPolicy, FifoPlan};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Engine, GraphBuilder, RunSummary};
use crate::{Error, Result};

/// A built multi-head graph: one engine, `H` independent head pipelines
/// (possibly heterogeneous shapes).
pub struct BuiltMultiHead {
    /// The shared engine.
    pub engine: Engine,
    /// Per-head output sinks.
    pub heads: Vec<SinkHandle>,
    /// Per-head `(n, d)` shapes, in head order.
    pub shapes: Vec<(usize, usize)>,
}

impl BuiltMultiHead {
    /// Largest sequence length across heads — the lane that bounds the
    /// run, since spatial pipelines finish independently.
    pub fn max_n(&self) -> usize {
        self.shapes.iter().map(|&(n, _)| n).max().unwrap_or(0)
    }

    /// Total scores the graph processes (Σ nᵢ² over heads).
    pub fn total_scores(&self) -> u64 {
        self.shapes.iter().map(|&(n, _)| (n * n) as u64).sum()
    }

    /// Run to completion, returning per-head outputs and the summary.
    /// The cycle budget covers the *slowest* lane — budgeting from head
    /// 0's shape used to starve runs whose later heads were larger.
    pub fn run(&mut self) -> Result<(Vec<Matrix>, RunSummary)> {
        let summary = self.engine.run(cycle_budget(self.max_n()))?;
        Ok((self.heads.iter().map(SinkHandle::rows).collect(), summary))
    }

    /// Aggregate scores processed per cycle for a completed run,
    /// computed from the actual per-lane workloads (Σ nᵢ², not
    /// `H · n₀²` — those differ as soon as lanes do).
    pub fn scores_per_cycle(&self, summary: &RunSummary) -> f64 {
        self.total_scores() as f64 / summary.cycles as f64
    }
}

/// Build one memory-free pipeline per workload, all in one engine, with
/// the given FIFO plan.
pub fn build_memfree_heads(
    workloads: &[Workload],
    plan: &FifoPlan,
) -> Result<BuiltMultiHead> {
    build_memfree_heads_with_policy(workloads, DepthPolicy::Explicit(*plan))
}

/// Build one memory-free pipeline per workload under a depth policy.
/// Head `i` lives in scope `h{i}`. Workloads may differ in shape;
/// empty or degenerate (n = 0 / d = 0) inputs are rejected with an
/// `Err` — never a panic, these are caller inputs.
pub fn build_memfree_heads_with_policy(
    workloads: &[Workload],
    policy: DepthPolicy,
) -> Result<BuiltMultiHead> {
    if workloads.is_empty() {
        return Err(Error::Graph(
            "multi-head build needs at least one workload".into(),
        ));
    }
    if let Some((h, w)) = workloads
        .iter()
        .enumerate()
        .find(|(_, w)| w.n == 0 || w.d == 0)
    {
        return Err(Error::Graph(format!(
            "head {h}: degenerate workload shape ({}, {})",
            w.n, w.d
        )));
    }
    let mut g = GraphBuilder::new();
    let mut heads = Vec::with_capacity(workloads.len());
    for (h, w) in workloads.iter().enumerate() {
        let mut scope = g.scope(format!("h{h}"));
        heads.push(memfree::build_into(&mut scope, w)?);
    }
    Ok(BuiltMultiHead {
        engine: g.compile(policy)?,
        heads,
        shapes: workloads.iter().map(|w| (w.n, w.d)).collect(),
    })
}

// ---------------------------------------------------------------------
// Decode lane pool
// ---------------------------------------------------------------------

/// One lane's pending decode step: a session's new query row against its
/// cached K/V rows. Lanes are heterogeneous by construction — every
/// session sits at its own cache length, and head dimensions may differ
/// across sessions.
pub struct LaneStep<'a> {
    /// Which decode-step mapping this lane runs.
    pub kind: DecodeKind,
    /// The lane index the owning session is pinned to (scope `lane{i}`;
    /// must be unique within one wave).
    pub lane: usize,
    /// Query row for the new token.
    pub q: &'a [f32],
    /// Cached key rows (all of the query's dimension).
    pub keys: &'a [Vec<f32>],
    /// Cached value rows.
    pub values: &'a [Vec<f32>],
}

/// A built decode wave: one engine, one independent decode-step pipeline
/// per lane. Produced by [`build_decode_lanes`]; each lane emits exactly
/// one output row.
pub struct BuiltLanePool {
    /// The shared engine.
    pub engine: Engine,
    /// Per-lane output sinks, in the order the steps were given.
    pub lanes: Vec<SinkHandle>,
    /// Per-lane cache lengths (the wave's workload profile).
    pub lens: Vec<usize>,
}

impl BuiltLanePool {
    /// Longest per-lane cache in the wave — bounds the wave's cycles.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Run the wave to completion: one output row per lane, plus the
    /// shared run summary (spatial execution ⇒ the wave's cycles track
    /// the longest lane, not the lane count).
    pub fn run(&mut self) -> Result<(Vec<Vec<f32>>, RunSummary)> {
        let summary = self.engine.run(cycle_budget(self.max_len()))?;
        let mut rows = Vec::with_capacity(self.lanes.len());
        for (i, lane) in self.lanes.iter().enumerate() {
            let mut out = lane.rows();
            if out.len() != 1 {
                return Err(Error::Coordinator(format!(
                    "lane {i}: expected one decode row, got {}",
                    out.len()
                )));
            }
            rows.push(out.pop().expect("checked length 1"));
        }
        Ok((rows, summary))
    }

    /// Aggregate decode steps per cycle for a completed wave — the
    /// serving-throughput figure of merit (scales with lane count while
    /// per-step latency stays fixed).
    pub fn steps_per_cycle(&self, summary: &RunSummary) -> f64 {
        self.lanes.len() as f64 / summary.cycles as f64
    }
}

/// Build one engine carrying one decode-step pipeline per entry of
/// `steps` (scope `lane{i}` from each step's lane index). This is the
/// generalisation of the multi-head builder the serving loop runs every
/// scheduling iteration: heterogeneous shapes per lane are the normal
/// case, and every input problem is an `Err`, not a panic.
pub fn build_decode_lanes(
    steps: &[LaneStep<'_>],
    policy: DepthPolicy,
) -> Result<BuiltLanePool> {
    let rows: Vec<LaneStepRows<'_>> = steps
        .iter()
        .map(|s| LaneStepRows {
            kind: s.kind,
            lane: s.lane,
            q: s.q,
            keys: s.keys.iter().map(Vec::as_slice).collect(),
            values: s.values.iter().map(Vec::as_slice).collect(),
        })
        .collect();
    build_decode_lanes_rows(&rows, policy)
}

/// One lane's pending decode step as gathered rows — what the paged
/// KV-cache path produces: a [`BlockPool::view`]
/// (`crate::runtime::kvcache`) walk of the session's block table hands
/// its borrowed row slices straight here, no copies and no layout
/// assumptions.
pub struct LaneStepRows<'a> {
    /// Which decode-step mapping this lane runs.
    pub kind: DecodeKind,
    /// The lane index the owning session is pinned to (scope `lane{i}`;
    /// must be unique within one wave).
    pub lane: usize,
    /// Query row for the new token.
    pub q: &'a [f32],
    /// Cached key rows in cache order (all of the query's dimension).
    pub keys: Vec<&'a [f32]>,
    /// Cached value rows in cache order.
    pub values: Vec<&'a [f32]>,
}

/// [`build_decode_lanes`] over gathered rows (the paged serving path).
pub fn build_decode_lanes_rows(
    steps: &[LaneStepRows<'_>],
    policy: DepthPolicy,
) -> Result<BuiltLanePool> {
    if steps.is_empty() {
        return Err(Error::Graph("decode wave needs at least one lane".into()));
    }
    let mut g = GraphBuilder::new();
    let mut lanes = Vec::with_capacity(steps.len());
    for step in steps {
        let mut scope = g.scope(format!("lane{}", step.lane));
        lanes.push(build_step_rows_into(
            &mut scope,
            step.kind,
            step.q,
            &step.keys,
            &step.values,
        )?);
    }
    Ok(BuiltLanePool {
        engine: g.compile(policy)?,
        lanes,
        lens: steps.iter().map(|s| s.keys.len()).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f64};
    use super::*;

    fn heads(h: usize, n: usize, d: usize) -> Vec<Workload> {
        (0..h).map(|i| Workload::random(n, d, 900 + i as u64)).collect()
    }

    #[test]
    fn every_head_matches_its_reference() {
        let ws = heads(4, 12, 8);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(12)).unwrap();
        let (outs, _) = built.run().unwrap();
        assert_eq!(outs.len(), 4);
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "head output");
        }
    }

    #[test]
    fn inferred_heads_match_reference_too() {
        let ws = heads(2, 12, 4);
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        let (outs, summary) = built.run().unwrap();
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "inferred head output");
        }
        // Memory-free per head: the analysis finds no long FIFO anywhere.
        assert!(summary.depths.iter().all(|c| !c.is_long));
    }

    #[test]
    fn aggregate_throughput_scales_with_heads() {
        let n = 16;
        for h in [1usize, 2, 4, 8] {
            let ws = heads(h, n, 4);
            let mut built = build_memfree_heads(&ws, &FifoPlan::paper(n)).unwrap();
            let (_, summary) = built.run().unwrap();
            let spc = built.scores_per_cycle(&summary);
            // Spatial pipelines are independent: cycles stay ~N²+fill, so
            // aggregate throughput ≈ h scores/cycle.
            assert!(
                spc > 0.9 * h as f64 && spc <= h as f64,
                "h={h}: {spc} scores/cycle"
            );
        }
    }

    #[test]
    fn memory_stays_constant_per_head() {
        let ws = heads(4, 24, 4);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(24)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn heads_are_isolated_in_reports() {
        let ws = heads(2, 8, 4);
        let built = build_memfree_heads(&ws, &FifoPlan::paper(8)).unwrap();
        let names = built.engine.channel_names();
        assert!(names.iter().any(|n| n == "h0/run_max"));
        assert!(names.iter().any(|n| n == "h1/run_max"));
    }

    #[test]
    fn heterogeneous_head_shapes_are_supported() {
        // Regression: heterogeneous workloads used to panic an
        // assert_eq!; the lane pool needs them to *work*. Shapes differ
        // in both n and d.
        let ws = vec![
            Workload::random(4, 4, 1),
            Workload::random(16, 8, 2),
            Workload::random(9, 2, 3),
        ];
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        assert_eq!(built.shapes, vec![(4, 4), (16, 8), (9, 2)]);
        let (outs, summary) = built.run().unwrap();
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "heterogeneous head");
        }
        // Aggregate throughput must come from the actual workloads
        // (Σ nᵢ² = 16 + 256 + 81), not heads.len() · n₀². The largest
        // lane dominates the cycles, so the aggregate lands near 1
        // score/cycle — the stale formula would report ~0.13.
        let spc = built.scores_per_cycle(&summary);
        assert_eq!(built.total_scores(), 353);
        assert!(spc > 0.5 && spc < 1.6, "aggregate {spc} scores/cycle");
    }

    #[test]
    fn small_first_head_does_not_starve_the_cycle_budget() {
        // Regression: run() used to budget cycle_budget(head0.n); with a
        // tiny head 0 and a large head 1 the engine hit the budget long
        // before the big lane finished.
        let ws = vec![Workload::random(2, 2, 7), Workload::random(64, 4, 8)];
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        assert_eq!(built.max_n(), 64);
        let (outs, _) = built.run().unwrap();
        assert_close(&outs[1], &sdpa_f64(&ws[1]), 1e-4, "large second head");
    }

    #[test]
    fn empty_workloads_error_not_panic() {
        let err = build_memfree_heads_with_policy(&[], DepthPolicy::Inferred);
        assert!(matches!(err, Err(Error::Graph(msg)) if msg.contains("at least one")));
    }

    // ---- decode lane pool -------------------------------------------

    use super::super::reference::sdpa_online_f32_masked;
    use super::super::workload::Mask;
    use super::super::decode::build_step;

    /// Build the wave for the last step of each workload (session `s`
    /// sits at cache length `w.n`).
    fn last_steps(ws: &[Workload]) -> Vec<LaneStep<'_>> {
        ws.iter()
            .enumerate()
            .map(|(i, w)| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane: i,
                q: &w.q[w.n - 1],
                keys: &w.k,
                values: &w.v,
            })
            .collect()
    }

    #[test]
    fn heterogeneous_lanes_match_each_sessions_reference() {
        let ws = vec![
            Workload::random(3, 4, 0xA0),
            Workload::random(7, 2, 0xA1),
            Workload::random(12, 8, 0xA2),
        ];
        let steps = last_steps(&ws);
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        assert_eq!(pool.lens, vec![3, 7, 12]);
        assert_eq!(pool.max_len(), 12);
        let (rows, summary) = pool.run().unwrap();
        for (row, w) in rows.iter().zip(&ws) {
            let gold = sdpa_online_f32_masked(w, &Mask::Causal);
            assert_close(
                &vec![row.clone()],
                &vec![gold[w.n - 1].clone()],
                1e-6,
                "lane vs causal last row",
            );
        }
        assert!(pool.steps_per_cycle(&summary) > 0.0);
    }

    #[test]
    fn lanes_compute_bit_identically_to_solo_steps() {
        // The continuous-batching guarantee at its core: a lane's row is
        // bitwise the row the same step computes in its own engine,
        // regardless of what shares the wave.
        let ws = vec![
            Workload::random(5, 4, 0xB0),
            Workload::random(9, 4, 0xB1),
            Workload::random(2, 2, 0xB2),
        ];
        let steps = last_steps(&ws);
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        let (rows, _) = pool.run().unwrap();
        for (w, row) in ws.iter().zip(&rows) {
            let mut solo = build_step(
                DecodeKind::MemoryFree,
                &w.q[w.n - 1],
                &w.k,
                &w.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            let (solo_rows, _) = solo.run().unwrap();
            assert_eq!(&solo_rows[0], row, "wave row ≡ solo row bitwise");
        }
    }

    #[test]
    fn lane_scopes_carry_the_sticky_lane_index() {
        let ws = vec![Workload::random(3, 2, 1), Workload::random(4, 2, 2)];
        let steps: Vec<LaneStep<'_>> = ws
            .iter()
            .zip([5usize, 2])
            .map(|(w, lane)| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane,
                q: &w.q[w.n - 1],
                keys: &w.k,
                values: &w.v,
            })
            .collect();
        let pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        let names = pool.engine.channel_names();
        assert!(names.iter().any(|n| n.starts_with("lane5/")));
        assert!(names.iter().any(|n| n.starts_with("lane2/")));
        assert!(!names.iter().any(|n| n.starts_with("lane0/")));
    }

    #[test]
    fn wave_memory_stays_constant_per_lane() {
        // The paper's O(1) claim per pipeline, across a wave: every
        // channel of every lane peaks at ≤ 2 elements no matter the
        // per-lane cache lengths.
        let ws = vec![
            Workload::random(8, 4, 0xC0),
            Workload::random(32, 4, 0xC1),
            Workload::random(64, 4, 0xC2),
        ];
        let steps = last_steps(&ws);
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        let (_, summary) = pool.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn empty_wave_and_bad_lane_inputs_error_not_panic() {
        assert!(matches!(
            build_decode_lanes(&[], DepthPolicy::Inferred),
            Err(Error::Graph(_))
        ));
        // A lane with a ragged cache propagates the step validation Err.
        let keys = vec![vec![1.0f32, 2.0]];
        let values = vec![vec![1.0f32]];
        let steps = [LaneStep {
            kind: DecodeKind::MemoryFree,
            lane: 0,
            q: &[1.0, 2.0],
            keys: &keys,
            values: &values,
        }];
        assert!(matches!(
            build_decode_lanes(&steps, DepthPolicy::Inferred),
            Err(Error::Graph(_))
        ));
        // Duplicate lane indices collide on scope names → Err, no panic.
        let w = Workload::random(3, 2, 9);
        let dup: Vec<LaneStep<'_>> = (0..2)
            .map(|_| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane: 4,
                q: &w.q[2],
                keys: &w.k,
                values: &w.v,
            })
            .collect();
        assert!(matches!(
            build_decode_lanes(&dup, DepthPolicy::Inferred),
            Err(Error::Graph(msg)) if msg.contains("duplicate")
        ));
    }
}
