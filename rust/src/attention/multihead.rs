//! Multi-head / multi-lane attention as *spatial* scale-out.
//!
//! A streaming dataflow fabric scales attention throughput by placing
//! independent pipelines side by side — the execution model's answer to
//! a GPU's grid dimension. Two compositions live here, both built by
//! instantiating one pipeline per [`Scope`](crate::sim::Scope) so nodes
//! and channels are automatically namespaced and no builder code ever
//! concatenates name strings:
//!
//! * **Prefill heads** ([`build_memfree_heads`]): `H` memory-free
//!   (Figure 3c) pipelines, one per workload, sharing one engine. Heads
//!   may have *heterogeneous* shapes — each lane carries its own
//!   `(n, d)` and the aggregate throughput / cycle budget are computed
//!   from the actual per-lane workloads (a homogeneity `assert!` here
//!   used to panic the library on caller input; it is now an `Err`-free
//!   supported case, which the serving lane pool depends on).
//! * **Decode lanes** ([`build_decode_lanes`]): one decode *step* per
//!   active session (arbitrary per-lane cache length and head
//!   dimension), the engine one scheduling iteration of the
//!   continuous-batching server runs. Lanes share no channels, so each
//!   session's step computes bit-identically to the same step run alone
//!   — the property `tests/continuous_batching.rs` enforces.
//!
//! Because pipelines are independent, the engine simulates true spatial
//! parallelism: total cycles stay ≈ the slowest lane while *aggregate*
//! throughput grows with the lane count, and intermediate memory grows
//! linearly in lanes but stays O(1) in sequence length — the paper's
//! claim, per pipeline.

use super::decode::{build_chunk_segment_into, build_step_rows_into, DecodeKind, SoftmaxCarry};
use super::reference::Matrix;
use super::workload::Workload;
use super::{cycle_budget, memfree, DepthPolicy, FifoPlan};
use crate::sim::nodes::SinkHandle;
use crate::sim::{Engine, GraphBuilder, RunSummary};
use crate::{Error, Result};

/// A built multi-head graph: one engine, `H` independent head pipelines
/// (possibly heterogeneous shapes).
pub struct BuiltMultiHead {
    /// The shared engine.
    pub engine: Engine,
    /// Per-head output sinks.
    pub heads: Vec<SinkHandle>,
    /// Per-head `(n, d)` shapes, in head order.
    pub shapes: Vec<(usize, usize)>,
}

impl BuiltMultiHead {
    /// Largest sequence length across heads — the lane that bounds the
    /// run, since spatial pipelines finish independently.
    pub fn max_n(&self) -> usize {
        self.shapes.iter().map(|&(n, _)| n).max().unwrap_or(0)
    }

    /// Total scores the graph processes (Σ nᵢ² over heads).
    pub fn total_scores(&self) -> u64 {
        self.shapes.iter().map(|&(n, _)| (n * n) as u64).sum()
    }

    /// Run to completion, returning per-head outputs and the summary.
    /// The cycle budget covers the *slowest* lane — budgeting from head
    /// 0's shape used to starve runs whose later heads were larger.
    pub fn run(&mut self) -> Result<(Vec<Matrix>, RunSummary)> {
        let summary = self.engine.run(cycle_budget(self.max_n()))?;
        Ok((self.heads.iter().map(SinkHandle::rows).collect(), summary))
    }

    /// Aggregate scores processed per cycle for a completed run,
    /// computed from the actual per-lane workloads (Σ nᵢ², not
    /// `H · n₀²` — those differ as soon as lanes do).
    pub fn scores_per_cycle(&self, summary: &RunSummary) -> f64 {
        self.total_scores() as f64 / summary.cycles as f64
    }
}

/// Build one memory-free pipeline per workload, all in one engine, with
/// the given FIFO plan.
pub fn build_memfree_heads(
    workloads: &[Workload],
    plan: &FifoPlan,
) -> Result<BuiltMultiHead> {
    build_memfree_heads_with_policy(workloads, DepthPolicy::Explicit(*plan))
}

/// Build one memory-free pipeline per workload under a depth policy.
/// Head `i` lives in scope `h{i}`. Workloads may differ in shape;
/// empty or degenerate (n = 0 / d = 0) inputs are rejected with an
/// `Err` — never a panic, these are caller inputs.
pub fn build_memfree_heads_with_policy(
    workloads: &[Workload],
    policy: DepthPolicy,
) -> Result<BuiltMultiHead> {
    if workloads.is_empty() {
        return Err(Error::Graph(
            "multi-head build needs at least one workload".into(),
        ));
    }
    if let Some((h, w)) = workloads
        .iter()
        .enumerate()
        .find(|(_, w)| w.n == 0 || w.d == 0)
    {
        return Err(Error::Graph(format!(
            "head {h}: degenerate workload shape ({}, {})",
            w.n, w.d
        )));
    }
    let mut g = GraphBuilder::new();
    let mut heads = Vec::with_capacity(workloads.len());
    for (h, w) in workloads.iter().enumerate() {
        let mut scope = g.scope(format!("h{h}"));
        heads.push(memfree::build_into(&mut scope, w)?);
    }
    Ok(BuiltMultiHead {
        engine: g.compile(policy)?,
        heads,
        shapes: workloads.iter().map(|w| (w.n, w.d)).collect(),
    })
}

// ---------------------------------------------------------------------
// Decode lane pool
// ---------------------------------------------------------------------

/// One lane's pending decode step: a session's new query row against its
/// cached K/V rows. Lanes are heterogeneous by construction — every
/// session sits at its own cache length, and head dimensions may differ
/// across sessions.
pub struct LaneStep<'a> {
    /// Which decode-step mapping this lane runs.
    pub kind: DecodeKind,
    /// The lane index the owning session is pinned to (scope `lane{i}`;
    /// must be unique within one wave).
    pub lane: usize,
    /// Query row for the new token.
    pub q: &'a [f32],
    /// Cached key rows (all of the query's dimension).
    pub keys: &'a [Vec<f32>],
    /// Cached value rows.
    pub values: &'a [Vec<f32>],
}

/// A built decode wave: one engine, one independent decode-step pipeline
/// per lane. Produced by [`build_decode_lanes`]; each lane emits exactly
/// one output row.
pub struct BuiltLanePool {
    /// The shared engine.
    pub engine: Engine,
    /// Per-lane output sinks, in the order the steps were given.
    pub lanes: Vec<SinkHandle>,
    /// Per-lane cache lengths (the wave's workload profile).
    pub lens: Vec<usize>,
}

impl BuiltLanePool {
    /// Longest per-lane cache in the wave — bounds the wave's cycles.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Run the wave to completion: one output row per lane, plus the
    /// shared run summary (spatial execution ⇒ the wave's cycles track
    /// the longest lane, not the lane count).
    pub fn run(&mut self) -> Result<(Vec<Vec<f32>>, RunSummary)> {
        let summary = self.engine.run(cycle_budget(self.max_len()))?;
        let mut rows = Vec::with_capacity(self.lanes.len());
        for (i, lane) in self.lanes.iter().enumerate() {
            let mut out = lane.rows();
            if out.len() != 1 {
                return Err(Error::Coordinator(format!(
                    "lane {i}: expected one decode row, got {}",
                    out.len()
                )));
            }
            rows.push(out.pop().expect("checked length 1"));
        }
        Ok((rows, summary))
    }

    /// Aggregate decode steps per cycle for a completed wave — the
    /// serving-throughput figure of merit (scales with lane count while
    /// per-step latency stays fixed).
    pub fn steps_per_cycle(&self, summary: &RunSummary) -> f64 {
        self.lanes.len() as f64 / summary.cycles as f64
    }
}

/// Build one engine carrying one decode-step pipeline per entry of
/// `steps` (scope `lane{i}` from each step's lane index). This is the
/// generalisation of the multi-head builder the serving loop runs every
/// scheduling iteration: heterogeneous shapes per lane are the normal
/// case, and every input problem is an `Err`, not a panic.
pub fn build_decode_lanes(
    steps: &[LaneStep<'_>],
    policy: DepthPolicy,
) -> Result<BuiltLanePool> {
    let rows: Vec<LaneStepRows<'_>> = steps
        .iter()
        .map(|s| LaneStepRows {
            kind: s.kind,
            lane: s.lane,
            q: s.q,
            keys: s.keys.iter().map(Vec::as_slice).collect(),
            values: s.values.iter().map(Vec::as_slice).collect(),
        })
        .collect();
    build_decode_lanes_rows(&rows, policy)
}

/// One lane's pending decode step as gathered rows — what the paged
/// KV-cache path produces: a [`BlockPool::view`]
/// (`crate::runtime::kvcache`) walk of the session's block table hands
/// its borrowed row slices straight here, no copies and no layout
/// assumptions.
pub struct LaneStepRows<'a> {
    /// Which decode-step mapping this lane runs.
    pub kind: DecodeKind,
    /// The lane index the owning session is pinned to (scope `lane{i}`;
    /// must be unique within one wave).
    pub lane: usize,
    /// Query row for the new token.
    pub q: &'a [f32],
    /// Cached key rows in cache order (all of the query's dimension).
    pub keys: Vec<&'a [f32]>,
    /// Cached value rows in cache order.
    pub values: Vec<&'a [f32]>,
}

/// [`build_decode_lanes`] over gathered rows (the paged serving path).
pub fn build_decode_lanes_rows(
    steps: &[LaneStepRows<'_>],
    policy: DepthPolicy,
) -> Result<BuiltLanePool> {
    if steps.is_empty() {
        return Err(Error::Graph("decode wave needs at least one lane".into()));
    }
    let mut g = GraphBuilder::new();
    let mut lanes = Vec::with_capacity(steps.len());
    for step in steps {
        let mut scope = g.scope(format!("lane{}", step.lane));
        lanes.push(build_step_rows_into(
            &mut scope,
            step.kind,
            step.q,
            &step.keys,
            &step.values,
        )?);
    }
    Ok(BuiltLanePool {
        engine: g.compile(policy)?,
        lanes,
        lens: steps.iter().map(|s| s.keys.len()).collect(),
    })
}

// ---------------------------------------------------------------------
// Mixed decode + chunked-prefill waves
// ---------------------------------------------------------------------

/// One prefill chunk segment of a wave: query row `q` resuming its key
/// scan at `carry` over the gathered span `keys`/`values`. Scoped
/// `lane{lane}p{seg}`, so several segments of one session coexist with
/// each other (consecutive prompt rows in one wave) and never collide
/// with a decode step's `lane{lane}` scope.
pub struct LaneChunkRows<'a> {
    /// Which decode-step mapping the owning session runs. Segments that
    /// resume or stop mid-row require [`DecodeKind::MemoryFree`] (only
    /// the online-softmax recurrence has a carryable state); a
    /// fresh-carry finalizing segment is an ordinary whole-row step and
    /// works for either kind.
    pub kind: DecodeKind,
    /// The lane index the owning session is pinned to.
    pub lane: usize,
    /// Segment index within this wave (scope disambiguator).
    pub seg: usize,
    /// The prompt row's query.
    pub q: &'a [f32],
    /// The key span this segment streams, in cache order.
    pub keys: Vec<&'a [f32]>,
    /// The value span this segment streams.
    pub values: Vec<&'a [f32]>,
    /// Online-softmax state entering the segment.
    pub carry: SoftmaxCarry,
    /// Whether this segment reaches the row's last visible key (sink
    /// emits the output row) or stops mid-row (sink emits the packed
    /// carry).
    pub finalize: bool,
}

/// One unit of work in a mixed wave: a session's decode step or one
/// prefill chunk segment.
pub enum LaneWork<'a> {
    /// An ordinary decode step (scope `lane{i}`).
    Step(LaneStepRows<'a>),
    /// A prefill chunk segment (scope `lane{i}p{j}`).
    Chunk(LaneChunkRows<'a>),
}

/// A built mixed wave: one engine, one independent pipeline per work
/// item. Decode-step sinks emit the step's output row; chunk sinks emit
/// either the finished prompt row (`finalize`) or the packed
/// `[m, r, ℓ⃗]` carry.
pub struct BuiltMixedWave {
    /// The shared engine.
    pub engine: Engine,
    /// Per-work-item output sinks, in the order the work was given.
    pub sinks: Vec<SinkHandle>,
    /// Per-work-item streamed lengths (cache len for steps, key-span
    /// len for chunks) — the wave's cycle budget tracks the longest.
    pub lens: Vec<usize>,
}

impl BuiltMixedWave {
    /// Longest streamed span in the wave — bounds the wave's cycles.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Run the wave to completion: one row per work item (output row or
    /// packed carry), plus the shared run summary.
    pub fn run(&mut self) -> Result<(Vec<Vec<f32>>, RunSummary)> {
        let summary = self.engine.run(cycle_budget(self.max_len()))?;
        let mut rows = Vec::with_capacity(self.sinks.len());
        for (i, sink) in self.sinks.iter().enumerate() {
            let mut out = sink.rows();
            if out.len() != 1 {
                return Err(Error::Coordinator(format!(
                    "wave item {i}: expected one row, got {}",
                    out.len()
                )));
            }
            rows.push(out.pop().expect("checked length 1"));
        }
        Ok((rows, summary))
    }
}

/// Build one engine carrying every work item of a mixed wave as its own
/// spatial pipeline — the generalisation of [`build_decode_lanes_rows`]
/// the budgeted scheduler runs: chunked prefill segments piggyback on
/// the decode wave, costing cycles like one more lane instead of one
/// more wave.
pub fn build_mixed_wave(work: &[LaneWork<'_>], policy: DepthPolicy) -> Result<BuiltMixedWave> {
    if work.is_empty() {
        return Err(Error::Graph("mixed wave needs at least one work item".into()));
    }
    let mut g = GraphBuilder::new();
    let mut sinks = Vec::with_capacity(work.len());
    let mut lens = Vec::with_capacity(work.len());
    for item in work {
        match item {
            LaneWork::Step(step) => {
                let mut scope = g.scope(format!("lane{}", step.lane));
                sinks.push(build_step_rows_into(
                    &mut scope,
                    step.kind,
                    step.q,
                    &step.keys,
                    &step.values,
                )?);
                lens.push(step.keys.len());
            }
            LaneWork::Chunk(chunk) => {
                let mut scope = g.scope(format!("lane{}p{}", chunk.lane, chunk.seg));
                if chunk.carry.is_fresh() && chunk.finalize {
                    // A whole fresh row is an ordinary step graph (and
                    // the only chunk shape the buffered mapping has).
                    sinks.push(build_step_rows_into(
                        &mut scope,
                        chunk.kind,
                        chunk.q,
                        &chunk.keys,
                        &chunk.values,
                    )?);
                } else {
                    if chunk.kind != DecodeKind::MemoryFree {
                        return Err(Error::Graph(format!(
                            "lane {}: only the memory-free mapping supports mid-row chunk \
                             segments ({} has no carryable softmax state)",
                            chunk.lane, chunk.kind
                        )));
                    }
                    sinks.push(build_chunk_segment_into(
                        &mut scope,
                        chunk.q,
                        &chunk.keys,
                        &chunk.values,
                        &chunk.carry,
                        chunk.finalize,
                    )?);
                }
                lens.push(chunk.keys.len());
            }
        }
    }
    Ok(BuiltMixedWave {
        engine: g.compile(policy)?,
        sinks,
        lens,
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f64};
    use super::*;

    fn heads(h: usize, n: usize, d: usize) -> Vec<Workload> {
        (0..h).map(|i| Workload::random(n, d, 900 + i as u64)).collect()
    }

    #[test]
    fn every_head_matches_its_reference() {
        let ws = heads(4, 12, 8);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(12)).unwrap();
        let (outs, _) = built.run().unwrap();
        assert_eq!(outs.len(), 4);
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "head output");
        }
    }

    #[test]
    fn inferred_heads_match_reference_too() {
        let ws = heads(2, 12, 4);
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        let (outs, summary) = built.run().unwrap();
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "inferred head output");
        }
        // Memory-free per head: the analysis finds no long FIFO anywhere.
        assert!(summary.depths.iter().all(|c| !c.is_long));
    }

    #[test]
    fn aggregate_throughput_scales_with_heads() {
        let n = 16;
        for h in [1usize, 2, 4, 8] {
            let ws = heads(h, n, 4);
            let mut built = build_memfree_heads(&ws, &FifoPlan::paper(n)).unwrap();
            let (_, summary) = built.run().unwrap();
            let spc = built.scores_per_cycle(&summary);
            // Spatial pipelines are independent: cycles stay ~N²+fill, so
            // aggregate throughput ≈ h scores/cycle.
            assert!(
                spc > 0.9 * h as f64 && spc <= h as f64,
                "h={h}: {spc} scores/cycle"
            );
        }
    }

    #[test]
    fn memory_stays_constant_per_head() {
        let ws = heads(4, 24, 4);
        let mut built = build_memfree_heads(&ws, &FifoPlan::paper(24)).unwrap();
        let (_, summary) = built.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn heads_are_isolated_in_reports() {
        let ws = heads(2, 8, 4);
        let built = build_memfree_heads(&ws, &FifoPlan::paper(8)).unwrap();
        let names = built.engine.channel_names();
        assert!(names.iter().any(|n| n == "h0/run_max"));
        assert!(names.iter().any(|n| n == "h1/run_max"));
    }

    #[test]
    fn heterogeneous_head_shapes_are_supported() {
        // Regression: heterogeneous workloads used to panic an
        // assert_eq!; the lane pool needs them to *work*. Shapes differ
        // in both n and d.
        let ws = vec![
            Workload::random(4, 4, 1),
            Workload::random(16, 8, 2),
            Workload::random(9, 2, 3),
        ];
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        assert_eq!(built.shapes, vec![(4, 4), (16, 8), (9, 2)]);
        let (outs, summary) = built.run().unwrap();
        for (out, w) in outs.iter().zip(&ws) {
            assert_close(out, &sdpa_f64(w), 1e-4, "heterogeneous head");
        }
        // Aggregate throughput must come from the actual workloads
        // (Σ nᵢ² = 16 + 256 + 81), not heads.len() · n₀². The largest
        // lane dominates the cycles, so the aggregate lands near 1
        // score/cycle — the stale formula would report ~0.13.
        let spc = built.scores_per_cycle(&summary);
        assert_eq!(built.total_scores(), 353);
        assert!(spc > 0.5 && spc < 1.6, "aggregate {spc} scores/cycle");
    }

    #[test]
    fn small_first_head_does_not_starve_the_cycle_budget() {
        // Regression: run() used to budget cycle_budget(head0.n); with a
        // tiny head 0 and a large head 1 the engine hit the budget long
        // before the big lane finished.
        let ws = vec![Workload::random(2, 2, 7), Workload::random(64, 4, 8)];
        let mut built =
            build_memfree_heads_with_policy(&ws, DepthPolicy::Inferred).unwrap();
        assert_eq!(built.max_n(), 64);
        let (outs, _) = built.run().unwrap();
        assert_close(&outs[1], &sdpa_f64(&ws[1]), 1e-4, "large second head");
    }

    #[test]
    fn empty_workloads_error_not_panic() {
        let err = build_memfree_heads_with_policy(&[], DepthPolicy::Inferred);
        assert!(matches!(err, Err(Error::Graph(msg)) if msg.contains("at least one")));
    }

    // ---- decode lane pool -------------------------------------------

    use super::super::reference::sdpa_online_f32_masked;
    use super::super::workload::Mask;
    use super::super::decode::build_step;

    /// Build the wave for the last step of each workload (session `s`
    /// sits at cache length `w.n`).
    fn last_steps(ws: &[Workload]) -> Vec<LaneStep<'_>> {
        ws.iter()
            .enumerate()
            .map(|(i, w)| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane: i,
                q: &w.q[w.n - 1],
                keys: &w.k,
                values: &w.v,
            })
            .collect()
    }

    #[test]
    fn heterogeneous_lanes_match_each_sessions_reference() {
        let ws = vec![
            Workload::random(3, 4, 0xA0),
            Workload::random(7, 2, 0xA1),
            Workload::random(12, 8, 0xA2),
        ];
        let steps = last_steps(&ws);
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        assert_eq!(pool.lens, vec![3, 7, 12]);
        assert_eq!(pool.max_len(), 12);
        let (rows, summary) = pool.run().unwrap();
        for (row, w) in rows.iter().zip(&ws) {
            let gold = sdpa_online_f32_masked(w, &Mask::Causal);
            assert_close(
                &vec![row.clone()],
                &vec![gold[w.n - 1].clone()],
                1e-6,
                "lane vs causal last row",
            );
        }
        assert!(pool.steps_per_cycle(&summary) > 0.0);
    }

    #[test]
    fn lanes_compute_bit_identically_to_solo_steps() {
        // The continuous-batching guarantee at its core: a lane's row is
        // bitwise the row the same step computes in its own engine,
        // regardless of what shares the wave.
        let ws = vec![
            Workload::random(5, 4, 0xB0),
            Workload::random(9, 4, 0xB1),
            Workload::random(2, 2, 0xB2),
        ];
        let steps = last_steps(&ws);
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        let (rows, _) = pool.run().unwrap();
        for (w, row) in ws.iter().zip(&rows) {
            let mut solo = build_step(
                DecodeKind::MemoryFree,
                &w.q[w.n - 1],
                &w.k,
                &w.v,
                DepthPolicy::Inferred,
            )
            .unwrap();
            let (solo_rows, _) = solo.run().unwrap();
            assert_eq!(&solo_rows[0], row, "wave row ≡ solo row bitwise");
        }
    }

    #[test]
    fn lane_scopes_carry_the_sticky_lane_index() {
        let ws = vec![Workload::random(3, 2, 1), Workload::random(4, 2, 2)];
        let steps: Vec<LaneStep<'_>> = ws
            .iter()
            .zip([5usize, 2])
            .map(|(w, lane)| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane,
                q: &w.q[w.n - 1],
                keys: &w.k,
                values: &w.v,
            })
            .collect();
        let pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        let names = pool.engine.channel_names();
        assert!(names.iter().any(|n| n.starts_with("lane5/")));
        assert!(names.iter().any(|n| n.starts_with("lane2/")));
        assert!(!names.iter().any(|n| n.starts_with("lane0/")));
    }

    #[test]
    fn wave_memory_stays_constant_per_lane() {
        // The paper's O(1) claim per pipeline, across a wave: every
        // channel of every lane peaks at ≤ 2 elements no matter the
        // per-lane cache lengths.
        let ws = vec![
            Workload::random(8, 4, 0xC0),
            Workload::random(32, 4, 0xC1),
            Workload::random(64, 4, 0xC2),
        ];
        let steps = last_steps(&ws);
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred).unwrap();
        let (_, summary) = pool.run().unwrap();
        for (name, st) in &summary.channel_stats {
            assert!(
                st.peak_occupancy_elems <= 2,
                "channel '{name}' peaked at {}",
                st.peak_occupancy_elems
            );
        }
    }

    #[test]
    fn empty_wave_and_bad_lane_inputs_error_not_panic() {
        assert!(matches!(
            build_decode_lanes(&[], DepthPolicy::Inferred),
            Err(Error::Graph(_))
        ));
        // A lane with a ragged cache propagates the step validation Err.
        let keys = vec![vec![1.0f32, 2.0]];
        let values = vec![vec![1.0f32]];
        let steps = [LaneStep {
            kind: DecodeKind::MemoryFree,
            lane: 0,
            q: &[1.0, 2.0],
            keys: &keys,
            values: &values,
        }];
        assert!(matches!(
            build_decode_lanes(&steps, DepthPolicy::Inferred),
            Err(Error::Graph(_))
        ));
        // Duplicate lane indices collide on scope names → Err, no panic.
        let w = Workload::random(3, 2, 9);
        let dup: Vec<LaneStep<'_>> = (0..2)
            .map(|_| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane: 4,
                q: &w.q[2],
                keys: &w.k,
                values: &w.v,
            })
            .collect();
        assert!(matches!(
            build_decode_lanes(&dup, DepthPolicy::Inferred),
            Err(Error::Graph(msg)) if msg.contains("duplicate")
        ));
    }

    #[test]
    fn mixed_wave_runs_steps_and_chunks_bit_identically_to_solo() {
        // One decode step and two prompt rows of another session share
        // a wave; every sink must emit exactly what the same work
        // computes alone.
        let dec = Workload::random(6, 4, 0xD0);
        let pre = Workload::random(5, 4, 0xD1);
        let dec_keys: Vec<&[f32]> = dec.k.iter().map(Vec::as_slice).collect();
        let dec_vals: Vec<&[f32]> = dec.v.iter().map(Vec::as_slice).collect();
        let pre_keys: Vec<&[f32]> = pre.k.iter().map(Vec::as_slice).collect();
        let pre_vals: Vec<&[f32]> = pre.v.iter().map(Vec::as_slice).collect();
        let work = vec![
            LaneWork::Step(LaneStepRows {
                kind: DecodeKind::MemoryFree,
                lane: 0,
                q: &dec.q[5],
                keys: dec_keys.clone(),
                values: dec_vals.clone(),
            }),
            // Prompt row 2 of the prefill session, whole (fresh, final).
            LaneWork::Chunk(LaneChunkRows {
                kind: DecodeKind::MemoryFree,
                lane: 1,
                seg: 0,
                q: &pre.q[2],
                keys: pre_keys[..3].to_vec(),
                values: pre_vals[..3].to_vec(),
                carry: SoftmaxCarry::fresh(4),
                finalize: true,
            }),
            // Prompt row 4, first two keys only (partial, carry out).
            LaneWork::Chunk(LaneChunkRows {
                kind: DecodeKind::MemoryFree,
                lane: 1,
                seg: 1,
                q: &pre.q[4],
                keys: pre_keys[..2].to_vec(),
                values: pre_vals[..2].to_vec(),
                carry: SoftmaxCarry::fresh(4),
                finalize: false,
            }),
        ];
        let mut wave = build_mixed_wave(&work, DepthPolicy::Inferred).unwrap();
        let names = wave.engine.channel_names();
        assert!(names.iter().any(|n| n.starts_with("lane0/")));
        assert!(names.iter().any(|n| n.starts_with("lane1p0/")));
        assert!(names.iter().any(|n| n.starts_with("lane1p1/")));
        let (rows, _) = wave.run().unwrap();
        assert_eq!(rows.len(), 3);

        let mut solo_step = build_step(
            DecodeKind::MemoryFree,
            &dec.q[5],
            &dec.k,
            &dec.v,
            DepthPolicy::Inferred,
        )
        .unwrap();
        let (solo_rows, _) = solo_step.run().unwrap();
        assert_eq!(rows[0], solo_rows[0], "decode lane ≡ solo step bitwise");

        let mut solo_pre = build_step(
            DecodeKind::MemoryFree,
            &pre.q[2],
            &pre.k[..3],
            &pre.v[..3],
            DepthPolicy::Inferred,
        )
        .unwrap();
        let (solo_pre_rows, _) = solo_pre.run().unwrap();
        assert_eq!(rows[1], solo_pre_rows[0], "whole-row chunk ≡ solo step");

        // The partial segment's carry resumes to the unsplit row 4.
        let carry = SoftmaxCarry::unpack(&rows[2]).unwrap();
        let resume = vec![LaneWork::Chunk(LaneChunkRows {
            kind: DecodeKind::MemoryFree,
            lane: 3,
            seg: 0,
            q: &pre.q[4],
            keys: pre_keys[2..].to_vec(),
            values: pre_vals[2..].to_vec(),
            carry,
            finalize: true,
        })];
        let mut wave2 = build_mixed_wave(&resume, DepthPolicy::Inferred).unwrap();
        let (rows2, _) = wave2.run().unwrap();
        let mut solo_full = build_step(
            DecodeKind::MemoryFree,
            &pre.q[4],
            &pre.k,
            &pre.v,
            DepthPolicy::Inferred,
        )
        .unwrap();
        let (solo_full_rows, _) = solo_full.run().unwrap();
        assert_eq!(rows2[0], solo_full_rows[0], "resumed chunk ≡ unsplit row");

        // Mid-row chunks demand the memory-free mapping.
        let bad = vec![LaneWork::Chunk(LaneChunkRows {
            kind: DecodeKind::Buffered,
            lane: 0,
            seg: 0,
            q: &pre.q[4],
            keys: pre_keys[..2].to_vec(),
            values: pre_vals[..2].to_vec(),
            carry: SoftmaxCarry::fresh(4),
            finalize: false,
        })];
        assert!(matches!(
            build_mixed_wave(&bad, DepthPolicy::Inferred),
            Err(Error::Graph(msg)) if msg.contains("memory-free")
        ));
        assert!(build_mixed_wave(&[], DepthPolicy::Inferred).is_err());
    }
}
