//! Figure 3(b) — division reordered past the PV contraction.
//!
//! By the distributive law, `o⃗_i = Σ_j (e_ij/σ_i)·v⃗_j = (Σ_j e_ij·v⃗_j)/σ_i`.
//! Moving the division after the value contraction makes the row-sum
//! reduction and the PV `MemReduce` run *in parallel on the same
//! element stream* — both consume e_ij at one element per cycle and
//! emit their row result after the Nth element, so their latencies
//! match and the second long FIFO of Figure 3(a) disappears:
//!
//! ```text
//! e ─ Broadcast ─→ Reduce(N, 0, +) ────────────→ r_i ─┐
//!        └───────→ Zip(e·v⃗) → MemReduce(N, 0⃗, +) → l⃗_i ─ Zip(l⃗/r) → o⃗_i
//! ```
//!
//! Only the score bypass (`s_bypass`, for the row max) still needs O(N)
//! depth — the depth analysis flags exactly that one channel here —
//! eliminated next by Figure 3(c).

use super::workload::{Mask, Workload};
use super::{score_frontend_masked, v_source, BuiltAttention, DepthPolicy, FifoPlan};
use crate::sim::{Elem, GraphBuilder};
use crate::Result;

/// Build the Figure-3(b) graph. `s_bypass` takes `plan.long`; everything
/// else (including the now-balanced e paths) takes `plan.short`.
pub fn build(w: &Workload, plan: &FifoPlan) -> Result<BuiltAttention> {
    build_with_policy(w, DepthPolicy::Explicit(*plan))
}

/// Figure-3(b) graph under a depth policy (`Inferred` derives N+2 for
/// `s_bypass` and depth 2 for the balanced e-side paths).
pub fn build_with_policy(w: &Workload, policy: DepthPolicy) -> Result<BuiltAttention> {
    build_masked_with_policy(w, &Mask::Full, policy)
}

/// Figure-3(b) graph with an in-stream [`Mask`] — masked positions ride
/// the stream as −∞ scores / zero exponentials; `s_bypass` keeps its
/// N+2 bound.
pub fn build_masked_with_policy(
    w: &Workload,
    mask: &Mask,
    policy: DepthPolicy,
) -> Result<BuiltAttention> {
    let n = w.n;
    let d = w.d;
    let mut g = GraphBuilder::new();
    let mut sc = g.root();

    let s = score_frontend_masked(&mut sc, w, mask)?;

    // Row max (still a row-wise reduction: the one remaining long FIFO).
    let [s_max, s_bypass] = sc.broadcast("bc_s", s, ["s_max", "s_bypass"])?;
    let m = sc.reduce("row_max", s_max, n, f32::NEG_INFINITY, f32::max)?;
    let m_rep = sc.repeat("rep_m", m, n)?;

    let e = sc.zip("exp_sub", [s_bypass, m_rep], |xs| {
        Elem::Scalar((xs[0].scalar() - xs[1].scalar()).exp())
    })?;

    // Balanced divergence: scalar sum and vector contraction in parallel.
    let [e_r, e_l] = sc.broadcast("bc_e", e, ["e_r", "e_l"])?;
    let r = sc.reduce("row_sum", e_r, n, 0.0, |a, b| a + b)?;

    let v_cols = v_source(&mut sc, w)?;
    let ev = sc.zip("ev_mul", [e_l, v_cols], |xs| {
        let e = xs[0].scalar();
        Elem::from(xs[1].as_vector().iter().map(|v| e * v).collect::<Vec<_>>())
    })?;
    let l = sc.mem_reduce("ev_acc", ev, n, vec![0.0; d], |acc, x| {
        acc.iter().zip(x.as_vector()).map(|(a, b)| a + b).collect()
    })?;

    // o⃗_i = l⃗_i / r_i — both operands arrive once per row, in step.
    let o = sc.zip("div", [l, r], |xs| {
        let r = xs[1].scalar();
        Elem::from(xs[0].as_vector().iter().map(|x| x / r).collect::<Vec<_>>())
    })?;
    let out = sc.sink("sink_o", o, Some(n as u64))?;

    Ok(BuiltAttention {
        engine: g.compile(policy)?,
        out,
        n,
        d,
    })
}

#[cfg(test)]
mod tests {
    use super::super::reference::{assert_close, sdpa_f32_scaled, sdpa_f64};
    use super::super::FifoPlan;
    use super::*;
    use crate::sim::metrics::is_full_throughput;
    use crate::sim::RunOutcome;

    #[test]
    fn matches_reference_numerics() {
        let w = Workload::random(12, 8, 300);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (got, _) = built.run().unwrap();
        // Division reordering changes f32 rounding slightly vs the
        // in-place division reference; both agree with f64 tightly.
        assert_close(&got, &sdpa_f32_scaled(&w), 1e-4, "reordered vs f32 ref");
        assert_close(&got, &sdpa_f64(&w), 1e-4, "reordered vs f64 ref");
    }

    #[test]
    fn paper_config_achieves_full_throughput() {
        let w = Workload::random(16, 4, 23);
        let mut finite = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, s_finite) = finite.run().unwrap();
        let mut base = build(&w, &FifoPlan::unbounded()).unwrap();
        let (_, s_base) = base.run().unwrap();
        assert!(is_full_throughput(&s_finite, &s_base));
    }

    #[test]
    fn only_s_bypass_is_order_n() {
        let w = Workload::random(16, 4, 24);
        let mut built = build(&w, &FifoPlan::paper(w.n)).unwrap();
        let (_, summary) = built.run().unwrap();
        let s_peak = summary.peak_elems("s_bypass").unwrap();
        assert!(s_peak >= w.n - 1, "s_bypass peak {} for N={}", s_peak, w.n);
        // The e-side paths are balanced: short FIFOs never exceed depth 2.
        for ch in ["e_r", "e_l", "ev_mul", "ev_acc", "row_sum"] {
            let peak = summary.peak_elems(ch).unwrap();
            assert!(peak <= 2, "{ch} peak {peak} should be O(1)");
        }
    }

    #[test]
    fn inference_flags_only_s_bypass() {
        let w = Workload::random(16, 4, 24);
        let built = build_with_policy(&w, DepthPolicy::Inferred).unwrap();
        let long: Vec<&str> = built
            .engine
            .depth_report()
            .iter()
            .filter(|c| c.is_long)
            .map(|c| c.name.as_str())
            .collect();
        assert_eq!(long, vec!["s_bypass"]);
    }

    #[test]
    fn short_s_bypass_deadlocks_but_e_paths_need_no_long_fifo() {
        let w = Workload::random(12, 4, 25);
        let mut built = build(&w, &FifoPlan::with_long_depth(2)).unwrap();
        assert!(matches!(
            built.run_outcome().outcome,
            RunOutcome::Deadlock { .. }
        ));
    }
}
