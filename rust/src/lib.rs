//! # sdpa-dataflow
//!
//! A production-quality reproduction of *"Implementing and Optimizing the
//! Scaled Dot-Product Attention on Streaming Dataflow"* (Sohn, Zhang,
//! Olukotun — Stanford, cs.AR 2024).
//!
//! The crate is organised as the paper's three-layer system:
//!
//! * [`sim`] — a cycle-accurate streaming-dataflow abstract machine
//!   (bounded FIFO channels with backpressure, Parallel-Pattern nodes per
//!   the paper's Table 1, deterministic two-phase engine, occupancy and
//!   throughput metrics, deadlock detection). This is our from-scratch
//!   stand-in for the Dataflow Abstract Machine simulator the paper used.
//! * [`attention`] — the four attention dataflow graphs the paper studies
//!   (Figure 2 naive, Figure 3a scaled softmax, Figure 3b reordered
//!   division, Figure 3c memory-free), plus a golden reference SDPA and
//!   deterministic workload generators.
//! * [`experiments`] — drivers that regenerate every table and figure in
//!   the paper (see `DESIGN.md` §5 for the experiment index).
//! * [`runtime`] — a PJRT wrapper that loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust.
//! * [`coordinator`] — a serving coordinator (router + dynamic batcher +
//!   worker pool) that drives the runtime on the request path with Python
//!   fully out of the loop.
//!
//! Supporting substrates built from scratch (the image has no offline
//! tokio/clap/criterion/proptest): [`cli`] argument parsing, [`bench`]
//! micro-benchmark harness, [`prng`] deterministic PRNG + property-test
//! helpers, and [`report`] tabular report formatting.

pub mod attention;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod prng;
pub mod report;
pub mod runtime;
pub mod sim;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Top-level error type for the library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// The simulated graph reached a configuration where no node can make
    /// progress but work remains — i.e. insufficient FIFO depth.
    #[error("deadlock at cycle {cycle}: {detail}")]
    Deadlock {
        /// Cycle at which the engine detected quiescence-with-work-left.
        cycle: u64,
        /// Human-readable description of the blocked nodes/channels.
        detail: String,
    },
    /// The simulation exceeded its configured cycle budget.
    #[error("simulation exceeded max_cycles={max_cycles}")]
    CycleBudgetExceeded {
        /// The configured budget.
        max_cycles: u64,
    },
    /// Graph construction error (dangling port, duplicate wiring, ...).
    #[error("graph construction: {0}")]
    Graph(String),
    /// Elements of the wrong kind flowed into a node (e.g. a vector where
    /// a scalar was expected).
    #[error("type error in node '{node}': {detail}")]
    ElemType {
        /// Name of the offending node.
        node: String,
        /// What went wrong.
        detail: String,
    },
    /// Runtime (PJRT / artifact) error.
    #[error("runtime: {0}")]
    Runtime(String),
    /// Coordinator error (queue closed, worker died, ...).
    #[error("coordinator: {0}")]
    Coordinator(String),
    /// CLI usage error.
    #[error("usage: {0}")]
    Usage(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}
