//! # sdpa-dataflow
//!
//! A production-quality reproduction of *"Implementing and Optimizing the
//! Scaled Dot-Product Attention on Streaming Dataflow"* (Sohn, Zhang,
//! Olukotun — Stanford, cs.AR 2024).
//!
//! The crate is organised as the paper's three-layer system:
//!
//! * [`sim`] — a cycle-accurate streaming-dataflow abstract machine
//!   (bounded FIFO channels with backpressure, Parallel-Pattern nodes per
//!   the paper's Table 1, deterministic two-phase engine, occupancy and
//!   throughput metrics, deadlock detection). Graphs are assembled with
//!   a port/scope builder whose `compile()` stage statically infers the
//!   latency-balancing FIFO depths (the paper's N+2). This is our
//!   from-scratch stand-in for the Dataflow Abstract Machine simulator
//!   the paper used.
//! * [`attention`] — the four attention dataflow graphs the paper studies
//!   (Figure 2 naive, Figure 3a scaled softmax, Figure 3b reordered
//!   division, Figure 3c memory-free), their causal (masked) twins and
//!   the autoregressive decode mapping, plus golden reference SDPAs
//!   (full, masked, online) and deterministic workload generators.
//! * [`experiments`] — drivers that regenerate every table and figure in
//!   the paper (see `DESIGN.md` §5 for the experiment index).
//! * [`runtime`] — loads the AOT-compiled JAX/Pallas artifact manifest
//!   (`artifacts/*.hlo.txt` + goldens) and executes the artifact
//!   functions from Rust (natively in-crate — the offline image has no
//!   PJRT; see `runtime::executor`).
//! * [`coordinator`] — a serving coordinator (router + dynamic prefill
//!   batcher + continuously-batched decode lane pool) that drives the
//!   runtime and the simulator on the request path with Python fully
//!   out of the loop.
//!
//! Supporting substrates built from scratch (the image has no offline
//! tokio/clap/criterion/proptest): [`cli`] argument parsing, [`bench`]
//! micro-benchmark harness, [`prng`] deterministic PRNG + property-test
//! helpers, and [`report`] tabular report formatting.

pub mod attention;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod envknob;
pub mod experiments;
pub mod prng;
pub mod report;
pub mod runtime;
pub mod sim;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Top-level error type for the library.
///
/// `Display`/`Error`/`From` are hand-implemented: the build image has no
/// offline crate registry, so the crate carries zero external
/// dependencies (no `thiserror`).
#[derive(Debug)]
pub enum Error {
    /// The simulated graph reached a configuration where no node can make
    /// progress but work remains — i.e. insufficient FIFO depth.
    Deadlock {
        /// Cycle at which the engine detected quiescence-with-work-left.
        cycle: u64,
        /// Human-readable description of the blocked nodes/channels.
        detail: String,
    },
    /// The simulation exceeded its configured cycle budget.
    CycleBudgetExceeded {
        /// The configured budget.
        max_cycles: u64,
    },
    /// Graph construction error (dangling port, duplicate wiring, ...).
    Graph(String),
    /// Elements of the wrong kind flowed into a node (e.g. a vector where
    /// a scalar was expected).
    ElemType {
        /// Name of the offending node.
        node: String,
        /// What went wrong.
        detail: String,
    },
    /// Runtime (PJRT / artifact) error.
    Runtime(String),
    /// Coordinator error (queue closed, worker died, ...).
    Coordinator(String),
    /// Admission was *deferred*, not refused: the request is valid but a
    /// bounded resource (session slot, pool lane, KV-cache block) is
    /// currently exhausted. Callers with a queue (the serving loop)
    /// requeue the work and retry after capacity frees instead of
    /// surfacing a hard failure.
    AdmissionDeferred(String),
    /// CLI usage error.
    Usage(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Deadlock { cycle, detail } => {
                write!(f, "deadlock at cycle {cycle}: {detail}")
            }
            Error::CycleBudgetExceeded { max_cycles } => {
                write!(f, "simulation exceeded max_cycles={max_cycles}")
            }
            Error::Graph(msg) => write!(f, "graph construction: {msg}"),
            Error::ElemType { node, detail } => {
                write!(f, "type error in node '{node}': {detail}")
            }
            Error::Runtime(msg) => write!(f, "runtime: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator: {msg}"),
            Error::AdmissionDeferred(msg) => write!(f, "admission deferred: {msg}"),
            Error::Usage(msg) => write!(f, "usage: {msg}"),
            // Transparent: io errors print as themselves.
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
