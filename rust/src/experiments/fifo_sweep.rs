//! Figures 2 and 3(a–c): FIFO-depth vs throughput sweeps.
//!
//! For each variant this driver sweeps the depth of the variant's long
//! FIFO(s) and reports, per depth: outcome (completed / deadlock),
//! cycles, slowdown vs the infinite-FIFO baseline, and peak occupancy of
//! the deepest channel. The paper's claims appear directly in the rows:
//!
//! * naive/scaled/reordered deadlock below ~N and hit baseline cycles at
//!   N+2 with peak occupancy N+1 → O(N) intermediate memory;
//! * memfree completes at **depth 2** with baseline cycles and O(1)
//!   occupancy everywhere.

use crate::attention::workload::Workload;
use crate::attention::{DepthPolicy, FifoPlan, Variant};
use crate::report::{fmt_ratio, Table};
use crate::sim::{RunOutcome, RunSummary, SchedStats, SchedulerMode};
use crate::Result;

/// One sweep row.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    /// Long-FIFO depth used (`None` = unbounded baseline).
    pub depth: Option<usize>,
    /// Run summary (outcome may be deadlock).
    pub summary: RunSummary,
}

/// Full sweep result for one variant.
#[derive(Clone, Debug)]
pub struct SweepResult {
    /// Variant swept.
    pub variant: Variant,
    /// Sequence length.
    pub n: usize,
    /// Scheduler the sweep ran under.
    pub mode: SchedulerMode,
    /// Baseline (all FIFOs unbounded).
    pub baseline: RunSummary,
    /// Points, ascending by depth, baseline last.
    pub points: Vec<SweepPoint>,
    /// Long-FIFO depth the compile-time analysis derives
    /// (`DepthPolicy::Inferred`), `None` when the variant has no long
    /// FIFO. The sweep's empirical minimum must land exactly here.
    pub inferred_long_depth: Option<usize>,
}

impl SweepResult {
    /// Sum a scheduler counter over every run in the sweep: the
    /// baseline plus each depth point (the depth-None point *is* the
    /// baseline, so it is excluded to avoid double counting).
    fn total_ticks(&self, f: impl Fn(&SchedStats) -> u64) -> u64 {
        f(&self.baseline.sched)
            + self
                .points
                .iter()
                .filter(|p| p.depth.is_some())
                .map(|p| f(&p.summary.sched))
                .sum::<u64>()
    }

    /// Node ticks the scheduler executed, summed over every run in the
    /// sweep (baseline included).
    pub fn total_ticks_executed(&self) -> u64 {
        self.total_ticks(|s| s.node_ticks_executed)
    }

    /// Node ticks skipped vs. the dense loop, summed over the sweep
    /// (0 when `mode` is dense).
    pub fn total_ticks_skipped(&self) -> u64 {
        self.total_ticks(|s| s.node_ticks_skipped)
    }

    /// Smallest swept depth that completed at baseline cycles.
    pub fn min_full_throughput_depth(&self) -> Option<usize> {
        self.points
            .iter()
            .filter(|p| {
                p.depth.is_some()
                    && p.summary.outcome == RunOutcome::Completed
                    && p.summary.cycles == self.baseline.cycles
            })
            .filter_map(|p| p.depth)
            .min()
    }

    /// Render the paper-style table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "{} — {} (N={}): long-FIFO depth sweep",
                self.variant.figure(),
                self.variant.name(),
                self.n
            ),
            &["long depth", "outcome", "cycles", "slowdown", "peak occ (long)", "peak words (total)"],
        );
        for p in &self.points {
            let depth = p
                .depth
                .map(|d| d.to_string())
                .unwrap_or_else(|| "inf".into());
            let (outcome, cycles, slow) = match &p.summary.outcome {
                RunOutcome::Completed => (
                    "ok".to_string(),
                    p.summary.cycles.to_string(),
                    fmt_ratio(p.summary.cycles as f64 / self.baseline.cycles as f64),
                ),
                RunOutcome::Deadlock { .. } => {
                    ("DEADLOCK".to_string(), "-".into(), "-".into())
                }
                RunOutcome::BudgetExceeded => ("budget".to_string(), "-".into(), "-".into()),
            };
            let peak_long = self
                .variant
                .long_fifos()
                .iter()
                .filter_map(|f| p.summary.peak_elems(f))
                .max()
                .map(|v| v.to_string())
                .unwrap_or_else(|| "-".into());
            t.row(&[
                depth,
                outcome,
                cycles,
                slow,
                peak_long,
                p.summary.total_peak_words().to_string(),
            ]);
        }
        t.row(&[
            self.inferred_long_depth
                .map(|d| d.to_string())
                .unwrap_or_else(|| "- (no long FIFO)".into()),
            "inferred (compile-time)".into(),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
        t
    }
}

/// Depths swept for sequence length `n` (plus the unbounded baseline).
pub fn sweep_depths(n: usize) -> Vec<usize> {
    let mut v = vec![2, n / 2, n, n + 1, n + 2, n + 8];
    v.dedup();
    v.retain(|&d| d >= 2);
    v.sort_unstable();
    v.dedup();
    v
}

/// Run the sweep for one variant under the default (event-driven)
/// scheduler.
pub fn run(variant: Variant, n: usize, d: usize) -> Result<SweepResult> {
    run_with_mode(variant, n, d, SchedulerMode::EventDriven)
}

/// Run the sweep for one variant under an explicit scheduler mode.
///
/// The graph is built **once** per configuration family and re-swept by
/// reconfiguring the long-FIFO capacities in place
/// ([`Engine::set_capacity`](crate::sim::Engine::set_capacity) +
/// [`Engine::reset`](crate::sim::Engine::reset)) rather than recompiled
/// per depth; each point's [`RunSummary::depths`] reports the capacity
/// that actually ran.
pub fn run_with_mode(
    variant: Variant,
    n: usize,
    d: usize,
    mode: SchedulerMode,
) -> Result<SweepResult> {
    let w = Workload::random(n, d, 0xF1F0);
    let mut base = variant.build(&w, &FifoPlan::unbounded())?;
    base.engine.set_scheduler_mode(mode);
    let (_, baseline) = base.run()?;

    let depths = sweep_depths(n);
    let mut built = variant.build(&w, &FifoPlan::with_long_depth(depths[0]))?;
    built.engine.set_scheduler_mode(mode);
    let mut points = Vec::new();
    let mut first = true;
    for depth in depths {
        for fifo in variant.long_fifos() {
            built
                .engine
                .set_capacity(fifo, crate::sim::Capacity::Bounded(depth))?;
        }
        if !first {
            built.engine.reset();
        }
        first = false;
        let summary = built.run_outcome();
        points.push(SweepPoint {
            depth: Some(depth),
            summary,
        });
    }
    points.push(SweepPoint {
        depth: None,
        summary: baseline.clone(),
    });

    // Compile-time prediction of the sweep's answer.
    let inferred = variant.build_with_policy(&w, DepthPolicy::Inferred)?;
    let inferred_long_depth = inferred
        .engine
        .depth_report()
        .iter()
        .filter(|c| c.is_long)
        .map(|c| c.inferred)
        .max();

    Ok(SweepResult {
        variant,
        n,
        mode,
        baseline,
        points,
        inferred_long_depth,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn naive_needs_n_plus_2() {
        let r = run(Variant::Naive, 16, 4).unwrap();
        assert_eq!(r.min_full_throughput_depth(), Some(18), "paper: N+2");
        // Depth 2 deadlocks.
        let p2 = r.points.iter().find(|p| p.depth == Some(2)).unwrap();
        assert!(matches!(p2.summary.outcome, RunOutcome::Deadlock { .. }));
    }

    #[test]
    fn scaled_and_reordered_need_n_plus_2() {
        for v in [Variant::Scaled, Variant::Reordered] {
            let r = run(v, 16, 4).unwrap();
            assert_eq!(r.min_full_throughput_depth(), Some(18), "{v}");
        }
    }

    #[test]
    fn compile_time_inference_predicts_the_sweep() {
        for v in [Variant::Naive, Variant::Scaled, Variant::Reordered] {
            let r = run(v, 16, 4).unwrap();
            assert_eq!(
                r.inferred_long_depth,
                r.min_full_throughput_depth(),
                "{v}: static analysis vs empirical sweep"
            );
        }
        let r = run(Variant::MemoryFree, 16, 4).unwrap();
        assert_eq!(r.inferred_long_depth, None, "memfree has no long FIFO");
    }

    #[test]
    fn memfree_full_throughput_at_depth_2() {
        let r = run(Variant::MemoryFree, 16, 4).unwrap();
        assert_eq!(r.min_full_throughput_depth(), Some(2), "paper: O(1)");
        // Every point completes (no long FIFO to undersize).
        for p in &r.points {
            assert_eq!(p.summary.outcome, RunOutcome::Completed);
        }
    }

    #[test]
    fn sweep_is_scheduler_invariant_and_cheaper_event_driven() {
        let ev = run_with_mode(Variant::Naive, 32, 4, SchedulerMode::EventDriven).unwrap();
        let de = run_with_mode(Variant::Naive, 32, 4, SchedulerMode::Dense).unwrap();
        assert_eq!(
            ev.min_full_throughput_depth(),
            de.min_full_throughput_depth()
        );
        for (pe, pd) in ev.points.iter().zip(&de.points) {
            assert_eq!(pe.summary.cycles, pd.summary.cycles, "depth {:?}", pe.depth);
            assert_eq!(pe.summary.outcome, pd.summary.outcome, "depth {:?}", pe.depth);
        }
        assert!(
            ev.total_ticks_executed() < de.total_ticks_executed(),
            "event {} vs dense {}",
            ev.total_ticks_executed(),
            de.total_ticks_executed()
        );
        assert!(ev.total_ticks_skipped() > 0);
        assert_eq!(de.total_ticks_skipped(), 0);
    }

    #[test]
    fn table_renders_deadlock_and_ok_rows() {
        let r = run(Variant::Naive, 8, 4).unwrap();
        let text = r.table().render();
        assert!(text.contains("DEADLOCK"));
        assert!(text.contains("1.00x"));
        assert!(text.contains("inf"));
    }
}
