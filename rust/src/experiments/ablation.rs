//! Ablation: where does the paper's "N+2" come from?
//!
//! The minimum full-throughput depth of the Figure-2 bypass FIFO is not
//! a magic constant — it is set by the **latency imbalance between the
//! divergent paths** at the divider `Zip`. Two sweeps demonstrate the
//! mechanism:
//!
//! 1. **Common-path latency** (a deeper `exp` pipeline, before the
//!    broadcast) delays both paths equally → the minimum depth stays at
//!    N+2 regardless of latency.
//! 2. **Divergent-path latency** (extra pipeline stages on the row-sum
//!    path between `Reduce` and `Repeat`) widens the imbalance → every
//!    cycle of added latency costs exactly one more bypass slot:
//!    min depth = N+2+L.
//!
//! For each point the driver bisects the minimum bypass depth that
//! matches the unbounded baseline's cycle count.

use crate::attention::naive::{build_with_delays, build_with_delays_policy};
use crate::attention::workload::Workload;
use crate::attention::{BuiltAttention, DepthPolicy, FifoPlan};
use crate::report::Table;
use crate::sim::{Capacity, RunOutcome};
use crate::Result;

/// Which path the latency is injected on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LatencySite {
    /// `exp` unit, before the broadcast (shared by both paths).
    CommonPath,
    /// Extra stages on the row-sum path (one side only).
    DivergentPath,
}

impl LatencySite {
    fn label(self) -> &'static str {
        match self {
            LatencySite::CommonPath => "common (exp unit)",
            LatencySite::DivergentPath => "divergent (sum path)",
        }
    }
}

/// One ablation row.
#[derive(Clone, Debug)]
pub struct AblationPoint {
    /// Where the latency was injected.
    pub site: LatencySite,
    /// Injected latency (cycles).
    pub latency: u64,
    /// Minimum bypass depth achieving baseline cycles (empirical
    /// bisection over simulations).
    pub min_depth: usize,
    /// Bypass depth the compile-time analysis derives for the same
    /// configuration — must equal `min_depth`.
    pub inferred_depth: usize,
    /// Baseline (unbounded) cycles at this configuration.
    pub baseline_cycles: u64,
}

/// Full ablation result.
#[derive(Clone, Debug)]
pub struct AblationResult {
    /// Sequence length.
    pub n: usize,
    /// All measured points.
    pub points: Vec<AblationPoint>,
}

impl AblationResult {
    /// Points for one site, ascending in latency.
    pub fn site(&self, site: LatencySite) -> Vec<&AblationPoint> {
        self.points.iter().filter(|p| p.site == site).collect()
    }

    /// Render the table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Ablation — min bypass depth vs injected latency (N={})",
                self.n
            ),
            &["latency site", "L", "min depth", "inferred", "prediction", "baseline cycles"],
        );
        for p in &self.points {
            let prediction = match p.site {
                LatencySite::CommonPath => format!("{} (N+2, unchanged)", self.n + 2),
                LatencySite::DivergentPath => {
                    format!("{} (N+2+L)", self.n as u64 + 2 + p.latency)
                }
            };
            t.row(&[
                p.site.label().into(),
                p.latency.to_string(),
                p.min_depth.to_string(),
                p.inferred_depth.to_string(),
                prediction,
                p.baseline_cycles.to_string(),
            ]);
        }
        t
    }
}

/// Re-run the shared probe engine at one bypass depth: reconfigure the
/// `e_bypass` capacity in place and reset, instead of recompiling the
/// graph for every bisection step. The per-run depth report
/// ([`RunSummary::depths`](crate::sim::RunSummary::depths)) reflects
/// the reconfigured capacity.
fn cycles_at_depth(probe: &mut BuiltAttention, depth: usize) -> Result<Option<u64>> {
    probe.engine.set_capacity("e_bypass", Capacity::Bounded(depth))?;
    probe.engine.reset();
    let s = probe.run_outcome();
    Ok(match s.outcome {
        RunOutcome::Completed => Some(s.cycles),
        _ => None,
    })
}

fn min_depth(w: &Workload, exp_latency: u64, sigma_delay: u64) -> Result<(usize, u64)> {
    let mut base = build_with_delays(w, &FifoPlan::unbounded(), exp_latency, sigma_delay)?;
    let (_, bs) = base.run()?;
    // Bisect on [2, 2N+32]: cycles(depth) is monotone non-increasing in
    // depth and equals baseline from the minimum depth onward. One
    // probe engine serves every step.
    let (mut lo, mut hi) = (2usize, 2 * w.n + 32);
    let mut probe =
        build_with_delays(w, &FifoPlan::with_long_depth(hi), exp_latency, sigma_delay)?;
    debug_assert_eq!(cycles_at_depth(&mut probe, hi)?, Some(bs.cycles));
    while lo < hi {
        let mid = (lo + hi) / 2;
        match cycles_at_depth(&mut probe, mid)? {
            Some(c) if c == bs.cycles => hi = mid,
            _ => lo = mid + 1,
        }
    }
    Ok((lo, bs.cycles))
}

/// Compile-time counterpart of [`min_depth`]: the bypass depth the
/// static latency-balance analysis derives for this configuration.
fn inferred_depth(w: &Workload, exp_latency: u64, sigma_delay: u64) -> Result<usize> {
    let built = build_with_delays_policy(w, DepthPolicy::Inferred, exp_latency, sigma_delay)?;
    Ok(built
        .engine
        .depth_report()
        .iter()
        .filter(|c| c.is_long)
        .map(|c| c.inferred)
        .max()
        .unwrap_or(2))
}

/// Run both sweeps over `latencies`.
pub fn run(n: usize, d: usize, latencies: &[u64]) -> Result<AblationResult> {
    let w = Workload::random(n, d, 0xAB1A);
    let mut points = Vec::new();
    for &latency in latencies {
        let (depth, cycles) = min_depth(&w, latency, 0)?;
        points.push(AblationPoint {
            site: LatencySite::CommonPath,
            latency,
            min_depth: depth,
            inferred_depth: inferred_depth(&w, latency, 0)?,
            baseline_cycles: cycles,
        });
    }
    for &latency in latencies {
        let (depth, cycles) = min_depth(&w, 1, latency)?;
        points.push(AblationPoint {
            site: LatencySite::DivergentPath,
            latency,
            min_depth: depth,
            inferred_depth: inferred_depth(&w, 1, latency)?,
            baseline_cycles: cycles,
        });
    }
    Ok(AblationResult { n, points })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn common_path_latency_does_not_change_depth() {
        let r = run(16, 4, &[1, 2, 4]).unwrap();
        for p in r.site(LatencySite::CommonPath) {
            assert_eq!(p.min_depth, 18, "L={}: still N+2", p.latency);
        }
    }

    #[test]
    fn divergent_path_latency_costs_one_slot_each() {
        let r = run(16, 4, &[1, 2, 4]).unwrap();
        for p in r.site(LatencySite::DivergentPath) {
            assert_eq!(
                p.min_depth as u64,
                16 + 2 + p.latency,
                "L={}: N+2+L",
                p.latency
            );
        }
    }

    #[test]
    fn static_analysis_matches_empirical_bisection() {
        // The tentpole claim of the compile stage: its depth formula is
        // not a heuristic — at every ablation point it lands exactly on
        // the bisected minimum.
        let r = run(16, 4, &[1, 2, 4]).unwrap();
        for p in &r.points {
            assert_eq!(
                p.inferred_depth, p.min_depth,
                "{:?} L={}",
                p.site, p.latency
            );
        }
    }

    #[test]
    fn table_shows_both_sites() {
        let r = run(12, 4, &[1]).unwrap();
        let text = r.table().render();
        assert!(text.contains("common (exp unit)"));
        assert!(text.contains("divergent (sum path)"));
        assert!(text.contains("N+2+L"));
    }
}
