//! Sliding-window eviction study: pool pressure vs window size.
//!
//! Serving workload: `sessions` independent sessions each decode
//! `steps` tokens through continuous-batching waves on one shared
//! block pool sized so the *unwindowed* baseline just fits. The study
//! runs that baseline first (window "∞"), then the same workload under
//! each sliding window W, and reports per row:
//!
//! * **blocks/session** — the per-session block ceiling,
//!   `min(⌈steps/bs⌉, ⌈W/bs⌉)`: unwindowed caches grow with the
//!   sequence, windowed rings are flat;
//! * **peak occupancy** — high-water pool blocks over capacity. The
//!   baseline approaches 1.0; windowed rows stay near
//!   `sessions · ⌈W/bs⌉ / pool`;
//! * **evictions** — rows recycled by ring eviction (0 for the
//!   baseline, `sessions · (steps − ring rows)` once W ≪ steps);
//! * **deferrals** — wave steps deferred and retried. Windowing trades
//!   pool pressure for eviction, so these stay 0 here;
//! * **bit-identical** — every transcript equals the contiguous
//!   windowed [`DecodeSession`] chain bit for bit. Eviction may drop
//!   *cache* rows, never change what a step computes.
//!
//! `benches/window_throughput.rs` is the wall-clock twin emitting
//! `BENCH_window.json` for CI; `tests/windowed_conformance.rs` asserts
//! the same flat-ring and bit-identity properties differentially.

use crate::attention::decode::{DecodeKind, DecodeSession};
use crate::attention::workload::Workload;
use crate::coordinator::{DecodeStepRequest, SessionConfig, SessionTable};
use crate::report::Table;
use crate::runtime::kvcache::KvCacheConfig;
use crate::{Error, Result};

/// One window-size measurement. `window: None` is the unwindowed
/// baseline row.
#[derive(Clone, Debug)]
pub struct WindowPoint {
    /// Sliding window for this run (`None` = unwindowed baseline).
    pub window: Option<usize>,
    /// Per-session block ceiling: `min(⌈steps/bs⌉, ⌈W/bs⌉)`.
    pub ring_blocks: usize,
    /// High-water blocks in use across the run.
    pub peak_used_blocks: usize,
    /// Rows recycled by ring eviction across the run.
    pub evictions: u64,
    /// Wave steps deferred and retried.
    pub deferrals: u64,
    /// Every transcript bitwise equal to the contiguous (windowed)
    /// chain.
    pub bit_identical: bool,
}

/// Full window-size sweep at one serving shape.
#[derive(Clone, Debug)]
pub struct WindowResult {
    /// Concurrent sessions.
    pub sessions: usize,
    /// Tokens decoded per session.
    pub steps: usize,
    /// Head dimension.
    pub d: usize,
    /// Rows per block.
    pub block_size: usize,
    /// Shared pool capacity (blocks) every run used.
    pub pool_blocks: usize,
    /// Baseline row first, then one row per window in the given order.
    pub points: Vec<WindowPoint>,
}

impl WindowResult {
    /// Look up one point (`None` = the baseline row).
    pub fn point(&self, window: Option<usize>) -> Option<&WindowPoint> {
        self.points.iter().find(|p| p.window == window)
    }

    /// Peak occupancy over capacity for one point (0.0–1.0].
    pub fn peak_occupancy(&self, p: &WindowPoint) -> f64 {
        p.peak_used_blocks as f64 / self.pool_blocks as f64
    }

    /// Render the study table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Sliding-window eviction vs window size \
                 ({} sessions, steps={}, d={}, pool={}x{})",
                self.sessions, self.steps, self.d, self.pool_blocks, self.block_size
            ),
            &[
                "window",
                "blocks/session",
                "peak occupancy",
                "evictions",
                "deferrals",
                "bit-identical",
            ],
        );
        for p in &self.points {
            t.row(&[
                match p.window {
                    None => "∞".into(),
                    Some(w) => w.to_string(),
                },
                p.ring_blocks.to_string(),
                format!("{:.2}", self.peak_occupancy(p)),
                p.evictions.to_string(),
                p.deferrals.to_string(),
                if p.bit_identical { "YES".into() } else { "NO".into() },
            ]);
        }
        t
    }
}

/// Serve one full run — `sessions` sessions, `steps` waves — on a
/// fresh [`SessionTable`], all sessions sharing one pool, with the
/// serving loop's deferred-first rotation. This is the **single** run
/// driver: the study ([`run`]) and the wall-clock bench twin
/// (`benches/window_throughput.rs`) both call it, so the two can never
/// diverge. Workloads are seeded deterministically from the shape.
pub fn run_point(
    window: Option<usize>,
    sessions: usize,
    steps: usize,
    d: usize,
    block_size: usize,
    pool_blocks: usize,
) -> Result<WindowPoint> {
    if sessions == 0 || steps == 0 || d == 0 || block_size == 0 {
        return Err(Error::Usage(format!(
            "window study needs sessions/steps/d/block_size ≥ 1 \
             (got {sessions}/{steps}/{d}/{block_size})"
        )));
    }
    if window == Some(0) {
        return Err(Error::Usage("window size must be ≥ 1".into()));
    }
    let ws: Vec<Workload> = (0..sessions)
        .map(|s| Workload::random(steps, d, 0x57D0_0000 + s as u64))
        .collect();
    let mut table = SessionTable::new(SessionConfig {
        lanes: sessions,
        max_sessions: sessions,
        max_len: steps,
        kv: KvCacheConfig {
            block_size,
            num_blocks: pool_blocks,
        },
        ..SessionConfig::default()
    })?;
    let ids = (0..sessions)
        .map(|_| match window {
            Some(w) => table.open_windowed(d, w),
            None => table.open(d),
        })
        .collect::<Result<Vec<u64>>>()?;

    // One step per session per wave, deferred sessions first next wave
    // (the serving loop's rotation).
    let mut cursors = vec![0usize; sessions];
    let mut deferred: Vec<u64> = Vec::new();
    let mut peak_used = 0usize;
    let mut deferrals = 0u64;
    while cursors.iter().any(|&c| c < steps) {
        let mut order: Vec<usize> = (0..sessions).collect();
        order.sort_by_key(|&s| (!deferred.contains(&ids[s]), s));
        deferred.clear();
        let mut reqs = Vec::new();
        let mut members = Vec::new();
        for &s in &order {
            if cursors[s] < steps {
                let t = cursors[s];
                reqs.push(DecodeStepRequest {
                    session: ids[s],
                    q: ws[s].q[t].clone(),
                    k: ws[s].k[t].clone(),
                    v: ws[s].v[t].clone(),
                });
                members.push(s);
            }
        }
        let results = table.step_wave(&reqs);
        peak_used = peak_used.max(table.pool_used_blocks());
        let mut progressed = false;
        for (res, s) in results.into_iter().zip(members) {
            match res {
                Ok(_) => {
                    cursors[s] += 1;
                    progressed = true;
                }
                Err(Error::AdmissionDeferred(_)) => {
                    deferrals += 1;
                    deferred.push(ids[s]);
                }
                Err(e) => return Err(e),
            }
        }
        if !progressed {
            return Err(Error::Coordinator(format!(
                "window study stalled at window {window:?}"
            )));
        }
    }
    let evictions = table.pool_evictions();

    // Bit-identity against the contiguous (windowed) chains.
    let mut bit_identical = true;
    for (s, &id) in ids.iter().enumerate() {
        let transcript = table.close(id).expect("session open");
        let mut chain = match window {
            Some(w) => DecodeSession::new_windowed(DecodeKind::MemoryFree, d, w),
            None => DecodeSession::new(DecodeKind::MemoryFree, d),
        };
        for t in 0..steps {
            chain.step(ws[s].q[t].clone(), ws[s].k[t].clone(), ws[s].v[t].clone())?;
        }
        bit_identical &= transcript == *chain.outputs();
    }

    let ring_blocks = match window {
        Some(w) => steps.div_ceil(block_size).min(w.div_ceil(block_size)),
        None => steps.div_ceil(block_size),
    };
    Ok(WindowPoint {
        window,
        ring_blocks,
        peak_used_blocks: peak_used,
        evictions,
        deferrals,
        bit_identical,
    })
}

/// Run the sweep: the unwindowed baseline first, then every window in
/// `windows`, all against one pool sized so the baseline just fits
/// (`sessions · ⌈steps/block_size⌉ + 2` blocks). Every window must be
/// ≥ 1.
pub fn run(
    windows: &[usize],
    sessions: usize,
    steps: usize,
    d: usize,
    block_size: usize,
) -> Result<WindowResult> {
    if sessions == 0 || steps == 0 || d == 0 || block_size == 0 {
        return Err(Error::Usage(format!(
            "window study needs sessions/steps/d/block_size ≥ 1 \
             (got {sessions}/{steps}/{d}/{block_size})"
        )));
    }
    if windows.is_empty() {
        return Err(Error::Usage(
            "window study needs at least one window size".into(),
        ));
    }
    if windows.contains(&0) {
        return Err(Error::Usage("window size must be ≥ 1".into()));
    }
    let pool_blocks = sessions * steps.div_ceil(block_size) + 2;
    let mut points = Vec::new();
    for window in std::iter::once(None).chain(windows.iter().map(|&w| Some(w))) {
        points.push(run_point(window, sessions, steps, d, block_size, pool_blocks)?);
    }
    Ok(WindowResult {
        sessions,
        steps,
        d,
        block_size,
        pool_blocks,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windowed_rows_stay_flat_while_the_baseline_fills_the_pool() {
        let r = run(&[4, 2], 3, 12, 4, 2).unwrap();
        // pool = 3 · ⌈12/2⌉ + 2 = 20 blocks.
        assert_eq!(r.pool_blocks, 20);
        let base = r.point(None).unwrap();
        assert_eq!(base.ring_blocks, 6, "baseline grows with the sequence");
        assert_eq!(base.peak_used_blocks, 18, "baseline fills its demand");
        assert_eq!(base.evictions, 0, "no ring without a window");
        assert!(base.bit_identical);
        for w in [4usize, 2] {
            let p = r.point(Some(w)).unwrap();
            assert_eq!(p.ring_blocks, w.div_ceil(2), "ring is ⌈W/bs⌉ blocks");
            assert!(
                p.peak_used_blocks <= 3 * p.ring_blocks,
                "W={w}: occupancy capped at sessions · ring"
            );
            // Ring rows = ⌈W/bs⌉ · bs; each session evicts the rest.
            let ring_rows = w.div_ceil(2) * 2;
            assert_eq!(p.evictions, (3 * (12 - ring_rows)) as u64, "W={w}");
            assert_eq!(p.deferrals, 0, "eviction replaces pool pressure");
            assert!(p.bit_identical, "W={w}: eviction never changes outputs");
        }
    }

    #[test]
    fn same_shape_same_numbers() {
        let key = |r: &WindowResult| {
            r.points
                .iter()
                .map(|p| (p.window, p.peak_used_blocks, p.evictions, p.deferrals))
                .collect::<Vec<_>>()
        };
        let a = run(&[3], 2, 8, 3, 2).unwrap();
        let b = run(&[3], 2, 8, 3, 2).unwrap();
        assert_eq!(key(&a), key(&b), "the sweep is deterministic");
    }

    #[test]
    fn table_labels_the_baseline_and_every_window() {
        let r = run(&[5], 2, 6, 3, 2).unwrap();
        let text = r.table().render();
        assert!(text.contains("∞"), "{text}");
        assert!(text.contains("bit-identical"), "{text}");
        assert!(r.point(Some(5)).is_some() && r.point(Some(7)).is_none());
    }

    #[test]
    fn degenerate_args_rejected() {
        assert!(matches!(run(&[], 2, 4, 2, 2), Err(Error::Usage(_))));
        assert!(matches!(run(&[0], 2, 4, 2, 2), Err(Error::Usage(_))));
        assert!(matches!(run(&[2], 0, 4, 2, 2), Err(Error::Usage(_))));
        assert!(matches!(run(&[2], 2, 0, 2, 2), Err(Error::Usage(_))));
        assert!(matches!(
            run_point(Some(0), 2, 4, 2, 2, 8),
            Err(Error::Usage(_))
        ));
    }
}
