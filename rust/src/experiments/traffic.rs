//! Trace-driven fleet study: offered load × shard count → aggregate
//! and per-shard throughput with TTFT / inter-token latency
//! percentiles, plus a built-in conformance check.
//!
//! This is the open-loop counterpart of [`super::serving`]: instead of
//! saturating a lane pool with a closed wave, it generates a seeded
//! bursty [`Trace`] per offered load (sessions per kilocycle during ON
//! windows), replays it through fleets of F ∈ shard_counts independent
//! fabrics on a virtual clock, and reports how the deployment-level
//! metrics move. Every (load, shards) cell replays twice — once under
//! the legacy [`SchedPolicy::Flush`] planner and once under a
//! token-budgeted [`SchedPolicy::Budgeted`] planner with chunked
//! prefill — so the table shows what budgeting buys (TTFT tail) and
//! costs (ITL) side by side. Every replay's served transcripts are
//! differentially compared against the standalone [`DecodeSession`]
//! oracle ([`Trace::oracle_transcripts`]) — the `bit_identical` column
//! is the acceptance flag, and `tests/fleet_conformance.rs` asserts
//! the same property across scheduler modes.
//! `benches/fleet_throughput.rs` is the wall-clock twin emitting
//! `BENCH_fleet.json` for CI; `benches/sched_throughput.rs` emits the
//! flush-vs-budgeted `BENCH_sched.json` with its TTFT regression
//! guard.
//!
//! [`DecodeSession`]: crate::attention::decode::DecodeSession

use crate::attention::decode::DecodeKind;
use crate::coordinator::fleet::{replay, FleetConfig};
use crate::coordinator::sched::{SchedPolicy, SchedulerConfig};
use crate::coordinator::traffic::{Arrivals, LenDist, Trace, TrafficConfig};
use crate::coordinator::SessionConfig;
use crate::report::Table;
use crate::runtime::kvcache::KvCacheConfig;
use crate::{Error, Result};

/// One (offered load, shard count, policy, scope) measurement —
/// `shard: None` is the fleet aggregate, `Some(s)` one shard's share.
#[derive(Clone, Debug)]
pub struct TrafficPoint {
    /// Offered load: arrival rate during ON windows (sessions per
    /// kilocycle).
    pub load: f64,
    /// Fleet width the trace was replayed against.
    pub shards: usize,
    /// `None` = fleet aggregate row, `Some(s)` = shard `s`'s row.
    pub shard: Option<usize>,
    /// Wave-planning policy the replay ran under (`"flush"` or
    /// `"budgeted"`).
    pub sched: &'static str,
    /// Decode steps served in this scope.
    pub steps: u64,
    /// Steps per 1000 virtual cycles over the replay's span.
    pub steps_per_kilocycle: f64,
    /// Median time-to-first-token (virtual cycles).
    pub ttft_p50: u64,
    /// p95 time-to-first-token (virtual cycles).
    pub ttft_p95: u64,
    /// p99 time-to-first-token (virtual cycles) — the budgeted
    /// planner's headline metric.
    pub ttft_p99: u64,
    /// Median inter-token gap (virtual cycles).
    pub itl_p50: u64,
    /// p95 inter-token gap (virtual cycles).
    pub itl_p95: u64,
    /// Deferred admissions/steps charged to this scope.
    pub deferrals: u64,
    /// Aggregate rows only: every served transcript matched the
    /// standalone oracle bit-for-bit. (Per-shard rows echo their
    /// fleet's flag.)
    pub bit_identical: bool,
}

/// Full offered-load × shard-count study.
#[derive(Clone, Debug)]
pub struct TrafficResult {
    /// Sessions per trace.
    pub sessions: usize,
    /// Head dimension.
    pub d: usize,
    /// Rows grouped by (load, shards): the aggregate row first, then
    /// one row per shard.
    pub points: Vec<TrafficPoint>,
}

impl TrafficResult {
    /// Look up the fleet-aggregate point for one (load, shards,
    /// policy) cell.
    pub fn aggregate(&self, load: f64, shards: usize, sched: &str) -> Option<&TrafficPoint> {
        self.points.iter().find(|p| {
            p.load == load && p.shards == shards && p.sched == sched && p.shard.is_none()
        })
    }

    /// Render the study table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Trace-driven fleet replay ({} sessions/trace, d={}, bursty arrivals)",
                self.sessions, self.d
            ),
            &[
                "load (sess/kcyc)",
                "shards",
                "sched",
                "scope",
                "steps",
                "steps/kcyc",
                "ttft p50/p95/p99 (cyc)",
                "itl p50/p95 (cyc)",
                "deferrals",
                "oracle-exact",
            ],
        );
        for p in &self.points {
            let scope = match p.shard {
                None => "fleet".to_string(),
                Some(s) => format!("shard {s}"),
            };
            t.row(&[
                format!("{:.1}", p.load),
                p.shards.to_string(),
                p.sched.to_string(),
                scope,
                p.steps.to_string(),
                format!("{:.2}", p.steps_per_kilocycle),
                format!("{}/{}/{}", p.ttft_p50, p.ttft_p95, p.ttft_p99),
                format!("{}/{}", p.itl_p50, p.itl_p95),
                p.deferrals.to_string(),
                if p.bit_identical { "yes" } else { "NO" }.to_string(),
            ]);
        }
        t
    }
}

/// Per-shard policy sized so the study measures routing and load, not
/// resource starvation: every shard alone can hold the whole trace
/// (lanes and blocks), so fork-heavy traces can never wedge on a
/// parent gated behind an unadmittable child. Pool-pressure behavior
/// is covered separately by `tests/fleet_conformance.rs`.
fn shard_policy(trace: &Trace) -> SessionConfig {
    let block_size = 4;
    let lanes = trace.sessions.len();
    let per_session = trace.max_rows().div_ceil(block_size).max(1);
    SessionConfig {
        lanes,
        max_sessions: lanes,
        kv: KvCacheConfig {
            block_size,
            num_blocks: per_session * lanes + 8,
        },
        ..SessionConfig::default()
    }
}

/// The budgeted policy the study compares against flush: chunked
/// prefill (4 rows per session per wave) under budgets generous enough
/// that the roomy shard policy never starves — the table then isolates
/// the chunking/priority effect rather than budget throttling.
fn budgeted_policy() -> SchedPolicy {
    SchedPolicy::Budgeted(SchedulerConfig {
        max_batch_prefill_tokens: 64,
        max_batch_total_tokens: 4096,
        prefill_chunk: 4,
        ..SchedulerConfig::default()
    })
}

/// Run the study: one seeded bursty trace per offered load (a quarter
/// each interactive/bulk, the rest standard), replayed against each
/// shard count under both wave planners. Every element of `loads` must
/// be > 0 and of `shard_counts` ≥ 1.
pub fn run(
    loads: &[f64],
    shard_counts: &[usize],
    sessions: usize,
    d: usize,
    seed: u64,
) -> Result<TrafficResult> {
    if sessions == 0 || d == 0 {
        return Err(Error::Usage(format!(
            "traffic study needs sessions ≥ 1 and d ≥ 1 (got {sessions} and {d})"
        )));
    }
    if loads.is_empty() || shard_counts.is_empty() {
        return Err(Error::Usage(
            "traffic study needs at least one load and one shard count".into(),
        ));
    }
    if let Some(bad) = loads.iter().find(|&&l| l <= 0.0) {
        return Err(Error::Usage(format!("offered load must be > 0 (got {bad})")));
    }
    if shard_counts.contains(&0) {
        return Err(Error::Usage("shard count must be ≥ 1".into()));
    }
    let mut points = Vec::new();
    for &load in loads {
        let cfg = TrafficConfig {
            sessions,
            d,
            arrivals: Arrivals::Bursty {
                rate: load,
                mean_on: 2.0,
                mean_off: 4.0,
            },
            prompt: LenDist::Uniform { lo: 4, hi: 10 },
            output: LenDist::Uniform { lo: 2, hi: 8 },
            fork_fraction: 0.25,
            abandon_fraction: 0.2,
            interactive_fraction: 0.25,
            bulk_fraction: 0.25,
            window: None,
            seed: seed ^ load.to_bits(),
        };
        let trace = Trace::generate(&cfg)?;
        let oracle = trace.oracle_transcripts(DecodeKind::MemoryFree)?;
        for &shards in shard_counts {
            for policy in [SchedPolicy::Flush, budgeted_policy()] {
                let fleet_cfg = FleetConfig {
                    shards,
                    sessions: shard_policy(&trace),
                    policy,
                };
                let rep = replay(&trace, fleet_cfg)?;
                let bit_identical = trace
                    .sessions
                    .iter()
                    .all(|s| rep.transcripts.get(&s.id) == oracle.get(&s.id));
                let total_cycles = rep.rollup.total_cycles();
                let mut push_scope = |shard: Option<usize>| {
                    let r = match shard {
                        None => rep.rollup.aggregate(),
                        Some(s) => rep.rollup.shard(s),
                    };
                    points.push(TrafficPoint {
                        load,
                        shards,
                        shard,
                        sched: policy.name(),
                        steps: r.steps(),
                        steps_per_kilocycle: r.steps_per_kilocycle(total_cycles),
                        ttft_p50: r.ttft().pct(0.50).unwrap_or(0),
                        ttft_p95: r.ttft().pct(0.95).unwrap_or(0),
                        ttft_p99: r.ttft().pct(0.99).unwrap_or(0),
                        itl_p50: r.inter_token().pct(0.50).unwrap_or(0),
                        itl_p95: r.inter_token().pct(0.95).unwrap_or(0),
                        deferrals: r.deferrals(),
                        bit_identical,
                    });
                };
                push_scope(None);
                for s in 0..shards {
                    push_scope(Some(s));
                }
            }
        }
    }
    Ok(TrafficResult {
        sessions,
        d,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn study_reports_every_scope_and_matches_oracle() {
        let r = run(&[2.0], &[1, 2], 8, 3, 0x7A11).unwrap();
        // Per (load, F) cell: 2 policies × (1 aggregate row + F shard
        // rows).
        assert_eq!(r.points.len(), 2 * ((1 + 1) + (1 + 2)));
        for f in [1, 2] {
            for sched in ["flush", "budgeted"] {
                let agg = r.aggregate(2.0, f, sched).unwrap();
                assert!(
                    agg.bit_identical,
                    "F={f} {sched} transcripts must match the oracle"
                );
                assert!(agg.steps > 0);
                // Shard rows sum to the aggregate.
                let shard_steps: u64 = r
                    .points
                    .iter()
                    .filter(|p| p.shards == f && p.sched == sched && p.shard.is_some())
                    .map(|p| p.steps)
                    .sum();
                assert_eq!(shard_steps, agg.steps);
            }
            // Both planners serve the identical trace, so their step
            // totals agree exactly.
            assert_eq!(
                r.aggregate(2.0, f, "flush").unwrap().steps,
                r.aggregate(2.0, f, "budgeted").unwrap().steps
            );
        }
        let text = r.table().render();
        assert!(text.contains("fleet"), "{text}");
        assert!(text.contains("shard 1"), "{text}");
        assert!(text.contains("budgeted"), "{text}");
        assert!(text.contains("yes"), "{text}");
    }

    #[test]
    fn budgeted_prefill_keeps_ttft_tail_and_itl_sane() {
        // Bursty arrivals with 4–10-row prompts: chunked prefill (4
        // rows/wave) must not blow up either headline metric relative
        // to flush — the strict improvement claim lives in
        // `benches/sched_throughput.rs` where the scenario is tuned
        // for it; this guard keeps the experiment itself honest.
        let r = run(&[4.0], &[1], 8, 3, 0x7A12).unwrap();
        let flush = r.aggregate(4.0, 1, "flush").unwrap();
        let budgeted = r.aggregate(4.0, 1, "budgeted").unwrap();
        assert!(flush.bit_identical && budgeted.bit_identical);
        assert!(
            budgeted.ttft_p99 <= flush.ttft_p99.saturating_mul(2).max(8),
            "budgeted ttft p99 {} vs flush {}",
            budgeted.ttft_p99,
            flush.ttft_p99
        );
        assert!(
            budgeted.itl_p50 <= flush.itl_p50.saturating_mul(4).max(8),
            "budgeted itl p50 {} vs flush {}",
            budgeted.itl_p50,
            flush.itl_p50
        );
    }

    #[test]
    fn same_seed_same_numbers() {
        let a = run(&[1.5], &[2], 6, 2, 9).unwrap();
        let b = run(&[1.5], &[2], 6, 2, 9).unwrap();
        let key = |r: &TrafficResult| {
            r.points
                .iter()
                .map(|p| (p.steps, p.ttft_p50, p.itl_p50, p.deferrals))
                .collect::<Vec<_>>()
        };
        assert_eq!(key(&a), key(&b), "virtual-clock stats are deterministic");
    }

    #[test]
    fn degenerate_args_rejected() {
        assert!(matches!(run(&[], &[1], 4, 2, 0), Err(Error::Usage(_))));
        assert!(matches!(run(&[1.0], &[], 4, 2, 0), Err(Error::Usage(_))));
        assert!(matches!(run(&[0.0], &[1], 4, 2, 0), Err(Error::Usage(_))));
        assert!(matches!(run(&[1.0], &[0], 4, 2, 0), Err(Error::Usage(_))));
        assert!(matches!(run(&[1.0], &[1], 0, 2, 0), Err(Error::Usage(_))));
    }
}
