//! Experiment drivers — one per paper artifact (DESIGN.md §5).
//!
//! Every driver returns structured results *and* renders the table the
//! paper's claims correspond to, so `cargo run -- experiments all`
//! regenerates the full evaluation and the integration tests assert on
//! the same data the reports print.
//!
//! | Driver | Paper artifact |
//! |---|---|
//! | [`table1::run`] | Table 1 — node semantics |
//! | [`fifo_sweep::run`] | Figures 2 / 3(a) / 3(b) / 3(c) — FIFO-depth vs throughput |
//! | [`scaling::run`] | O(N) vs O(1) intermediate-memory growth |
//! | [`numerics::run`] | all variants (incl. causal/decode) ≡ their reference SDPA |
//! | [`ablation::run`] | extension: min FIFO depth = N+1+L(exp) latency study |
//! | [`decode::run`] | extension: decode-step cost/memory vs cache length |
//! | [`serving::run`] | extension: serving lane-pool throughput vs lane count |
//! | [`paging::run`] | extension: paged KV cache — prefix sharing + preemption vs pool size |
//! | [`traffic::run`] | extension: trace-driven fleet replay — throughput/TTFT/ITL vs offered load and shard count |
//! | [`window::run`] | extension: sliding-window eviction — pool occupancy/evictions vs window size |
//! | [`codesign::run`] | extension: FLASH-D vs reordered — nodes / FIFO slots / cycles / error per head |

pub mod ablation;
pub mod codesign;
pub mod decode;
pub mod fifo_sweep;
pub mod numerics;
pub mod paging;
pub mod scaling;
pub mod serving;
pub mod table1;
pub mod traffic;
pub mod window;

use crate::Result;

/// Run every experiment with default parameters (the `experiments all`
/// subcommand); prints each table to stdout.
pub fn run_all(n: usize, d: usize) -> Result<()> {
    table1::run().print();
    for v in crate::attention::Variant::PAPER {
        let r = fifo_sweep::run(v, n, d)?;
        r.table().print();
        println!();
    }
    scaling::run(&[16, 32, 64, 128], d)?.table().print();
    println!();
    numerics::run(n, d)?.table().print();
    println!();
    ablation::run(n.min(32), d, &[1, 2, 4])?.table().print();
    println!();
    decode::run(&[4, 16, 64], d)?.table().print();
    println!();
    serving::run(&[1, 2, 4, 8], n.clamp(1, 64), d)?.table().print();
    println!();
    paging::run(&[64, 16, 8], 4, 8, 4, d.min(16), 2)?.table().print();
    println!();
    traffic::run(&[2.0], &[1, 2], 8, d.min(8), 0x7A11)?.table().print();
    println!();
    window::run(&[8, 4, 2], 3, 12, d.min(8), 2)?.table().print();
    println!();
    codesign::run(&[16, 64], d.min(8))?.table().print();
    Ok(())
}
