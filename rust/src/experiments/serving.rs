//! Serving lane-pool scaling study: aggregate decode throughput vs
//! lane count at fixed per-step latency.
//!
//! The continuous-batching claim is the paper's spatial-independence
//! claim worn by the serving loop: decode lanes share no channels, so a
//! wave of `L` concurrent session steps completes in ≈ the cycles of
//! **one** step (the longest lane), while aggregate throughput grows to
//! `L` steps per wave. This driver builds one wave per lane count —
//! every lane a memory-free decode step at the same cache length — runs
//! it, and reports wave cycles (should stay flat — this *is* the
//! per-step latency, and its staying fixed as lanes grow is the claim),
//! aggregate steps per kilocycle (should grow ~linearly), and peak FIFO
//! occupancy (O(1) per lane, so the pool's peak per channel stays ≤ 2). `benches/serving_throughput.rs` is the
//! wall-clock twin emitting `BENCH_serving.json` for CI.

use crate::attention::decode::DecodeKind;
use crate::attention::multihead::{build_decode_lanes, LaneStep};
use crate::attention::workload::Workload;
use crate::attention::DepthPolicy;
use crate::report::Table;
use crate::Result;

/// One lane-count measurement.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    /// Concurrent lanes in the wave.
    pub lanes: usize,
    /// Cycles the wave took (its slowest lane) — this *is* every
    /// co-scheduled step's latency; staying fixed across lane counts is
    /// the spatial-independence claim.
    pub wave_cycles: u64,
    /// Aggregate decode steps per 1000 simulated cycles.
    pub steps_per_kilocycle: f64,
    /// Largest per-channel peak occupancy across the pool (elements).
    pub peak_elems: usize,
}

/// Full lane-scaling study at one `(len, d)` serving shape.
#[derive(Clone, Debug)]
pub struct ServingResult {
    /// Cache length every lane's step attends.
    pub len: usize,
    /// Head dimension.
    pub d: usize,
    /// Points ascending in lane count.
    pub points: Vec<ServingPoint>,
}

impl ServingResult {
    /// Look up one point.
    pub fn point(&self, lanes: usize) -> Option<&ServingPoint> {
        self.points.iter().find(|p| p.lanes == lanes)
    }

    /// Render the study table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Decode serving wave vs lane count (len={}, d={}, memfree)",
                self.len, self.d
            ),
            &[
                "lanes",
                "wave cycles (= per-step latency)",
                "steps/kilocycle",
                "peak FIFO (elems)",
            ],
        );
        for p in &self.points {
            t.row(&[
                p.lanes.to_string(),
                p.wave_cycles.to_string(),
                format!("{:.2}", p.steps_per_kilocycle),
                p.peak_elems.to_string(),
            ]);
        }
        t
    }
}

/// Run the study over ascending lane counts (each ≥ 1). Every lane runs
/// a memory-free decode step over its own random session cache of
/// `len` rows.
pub fn run(lane_counts: &[usize], len: usize, d: usize) -> Result<ServingResult> {
    if len == 0 || d == 0 {
        return Err(crate::Error::Usage(format!(
            "serving study needs len ≥ 1 and d ≥ 1 (got len={len}, d={d})"
        )));
    }
    let mut points = Vec::new();
    for &lanes in lane_counts {
        // Distinct per-lane session data, same length (the steady-state
        // serving profile; heterogeneous lengths are covered by the
        // multihead and coordinator tests).
        let ws: Vec<Workload> = (0..lanes)
            .map(|l| Workload::random(len, d, 0x5E21 + l as u64))
            .collect();
        let steps: Vec<LaneStep<'_>> = ws
            .iter()
            .enumerate()
            .map(|(l, w)| LaneStep {
                kind: DecodeKind::MemoryFree,
                lane: l,
                q: &w.q[len - 1],
                keys: &w.k,
                values: &w.v,
            })
            .collect();
        let mut pool = build_decode_lanes(&steps, DepthPolicy::Inferred)?;
        let (_, summary) = pool.run()?;
        let peak_elems = summary
            .channel_stats
            .iter()
            .map(|(_, st)| st.peak_occupancy_elems)
            .max()
            .unwrap_or(0);
        points.push(ServingPoint {
            lanes,
            wave_cycles: summary.cycles,
            steps_per_kilocycle: lanes as f64 * 1000.0 / summary.cycles as f64,
            peak_elems,
        });
    }
    Ok(ServingResult { len, d, points })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wave_cycles_stay_flat_as_lanes_grow() {
        // Spatial independence: 8 lanes cost ≈ the same cycles as 1.
        let r = run(&[1, 2, 4, 8], 32, 4).unwrap();
        let one = r.point(1).unwrap().wave_cycles as f64;
        let eight = r.point(8).unwrap().wave_cycles as f64;
        assert!(
            eight <= 1.1 * one,
            "8-lane wave {eight} cycles vs 1-lane {one} — not spatial"
        );
    }

    #[test]
    fn aggregate_throughput_scales_with_lanes() {
        let r = run(&[1, 4], 32, 4).unwrap();
        let t1 = r.point(1).unwrap().steps_per_kilocycle;
        let t4 = r.point(4).unwrap().steps_per_kilocycle;
        assert!(
            t4 > 3.5 * t1,
            "4 lanes: {t4} steps/kcyc vs 1 lane {t1} — expected ~4x"
        );
    }

    #[test]
    fn pool_memory_stays_constant_per_channel() {
        let r = run(&[1, 8], 24, 4).unwrap();
        for p in &r.points {
            assert!(p.peak_elems <= 2, "lanes={}: peak {}", p.lanes, p.peak_elems);
        }
    }

    #[test]
    fn table_lists_every_lane_count() {
        let r = run(&[1, 2], 8, 2).unwrap();
        let text = r.table().render();
        assert!(text.contains("steps/kilocycle"));
        assert!(r.point(2).is_some() && r.point(3).is_none());
    }
}
