//! Paged KV-cache pool-pressure scaling study.
//!
//! Serving workload: one parent session prefills a shared `prefix`,
//! `sessions − 1` children fork from it (refcounted blocks, zero
//! copies), and then every session decodes `steps` continuation tokens
//! through continuous-batching waves. The study sweeps the block-pool
//! size from ample to scarce and reports, per pool size:
//!
//! * **peak occupancy** — blocks in use at the high-water mark over the
//!   capacity (never exceeds 1.0: the pool is a hard bound, which is
//!   the point — contiguous caches had no bound at all);
//! * **shared blocks** — prefix blocks referenced by every fork
//!   (`prefix / block_size` when sharing works; the contiguous design
//!   stored this data once *per session*);
//! * **preemptions / deferrals / waves** — how much swapping and
//!   requeueing the pressure forced;
//! * **bit-identical** — whether every transcript still equals the
//!   unpressured contiguous [`DecodeSession`] chain bit for bit. This
//!   must hold at every pool size: pressure may cost time, never
//!   correctness.
//!
//! `benches/paging_throughput.rs` is the wall-clock twin emitting
//! `BENCH_paging.json` for CI.

use crate::attention::decode::{DecodeKind, DecodeSession};
use crate::attention::workload::Workload;
use crate::coordinator::{DecodeStepRequest, SessionConfig, SessionTable};
use crate::report::Table;
use crate::runtime::kvcache::KvCacheConfig;
use crate::sim::SchedulerMode;
use crate::{Error, Result};

/// One pool-size measurement.
#[derive(Clone, Debug)]
pub struct PagingPoint {
    /// Blocks in the pool for this run.
    pub num_blocks: usize,
    /// High-water blocks in use across the run.
    pub peak_used_blocks: usize,
    /// Shared blocks right after the forks (the prefix-sharing win).
    pub shared_blocks: usize,
    /// Sessions swapped out under pressure.
    pub preemptions: u64,
    /// Wave steps deferred and retried.
    pub deferrals: u64,
    /// Scheduling iterations needed to serve every step.
    pub waves: u64,
    /// Every transcript bitwise equal to the unpressured contiguous
    /// chain.
    pub bit_identical: bool,
}

impl PagingPoint {
    /// Peak occupancy over capacity (0.0–1.0].
    pub fn peak_occupancy(&self) -> f64 {
        self.peak_used_blocks as f64 / self.num_blocks as f64
    }
}

/// Full pool-pressure study at one serving shape.
#[derive(Clone, Debug)]
pub struct PagingResult {
    /// Concurrent sessions (1 parent + forks).
    pub sessions: usize,
    /// Shared prefix tokens decoded by the parent before forking.
    pub prefix: usize,
    /// Continuation tokens decoded by every session after the forks.
    pub steps: usize,
    /// Head dimension.
    pub d: usize,
    /// Rows per block.
    pub block_size: usize,
    /// Points in the order the pool sizes were given.
    pub points: Vec<PagingPoint>,
}

impl PagingResult {
    /// Look up one point.
    pub fn point(&self, num_blocks: usize) -> Option<&PagingPoint> {
        self.points.iter().find(|p| p.num_blocks == num_blocks)
    }

    /// Render the study table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Paged KV cache vs pool size ({} sessions, prefix={}, steps={}, d={}, block_size={})",
                self.sessions, self.prefix, self.steps, self.d, self.block_size
            ),
            &[
                "pool blocks",
                "peak occupancy",
                "shared blocks",
                "preemptions",
                "deferrals",
                "waves",
                "bit-identical",
            ],
        );
        for p in &self.points {
            t.row(&[
                p.num_blocks.to_string(),
                format!("{:.2}", p.peak_occupancy()),
                p.shared_blocks.to_string(),
                p.preemptions.to_string(),
                p.deferrals.to_string(),
                p.waves.to_string(),
                if p.bit_identical { "YES".into() } else { "NO".into() },
            ]);
        }
        t
    }
}

/// The (q, k, v) row session `s` feeds at step `t`: the first `prefix`
/// rows come from the shared workload (every session sees the same
/// prompt), later rows from the session's own continuation workload.
fn row(
    shared: &Workload,
    conts: &[Workload],
    prefix: usize,
    s: usize,
    t: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let w = if t < prefix { shared } else { &conts[s] };
    (w.q[t].clone(), w.k[t].clone(), w.v[t].clone())
}

/// What one full fork-and-decode episode did (see [`run_episode`]).
#[derive(Clone, Debug)]
pub struct EpisodeReport {
    /// Scheduling iterations needed to serve every step.
    pub waves: u64,
    /// Wave steps deferred and retried.
    pub deferrals: u64,
    /// High-water blocks in use across the episode.
    pub peak_used_blocks: usize,
    /// Shared blocks right after the forks.
    pub shared_blocks: usize,
    /// Sessions swapped out under pressure.
    pub preemptions: u64,
    /// Per-session transcripts, parent first then the forks in id
    /// order (the parent's includes the prefix rows; forks carry only
    /// their continuation).
    pub transcripts: Vec<Vec<Vec<f32>>>,
}

impl EpisodeReport {
    /// Decode steps the episode served (prefix + every continuation).
    pub fn total_steps(&self) -> usize {
        self.transcripts.iter().map(Vec::len).sum()
    }
}

/// Serve one complete episode on a fresh [`SessionTable`]: a parent
/// prefills the shared `prefix`, `sessions − 1` children fork from it,
/// then every session decodes `steps` continuation tokens through
/// continuous-batching waves with the serving loop's deferred-first
/// rotation. This is the **single** episode driver — the pool-pressure
/// study ([`run`]) and the wall-clock bench twin
/// (`benches/paging_throughput.rs`) both call it, so the two can never
/// diverge. Workloads are seeded deterministically from the shape.
pub fn run_episode(
    mode: Option<SchedulerMode>,
    sessions: usize,
    prefix: usize,
    steps: usize,
    d: usize,
    kv: KvCacheConfig,
) -> Result<EpisodeReport> {
    if sessions == 0 || steps == 0 || d == 0 || kv.block_size == 0 {
        return Err(Error::Usage(format!(
            "paging episode needs sessions/steps/d/block_size ≥ 1 \
             (got {sessions}/{steps}/{d}/{})",
            kv.block_size
        )));
    }
    let total = prefix + steps;
    let min_blocks = total.div_ceil(kv.block_size);
    if min_blocks > kv.num_blocks {
        return Err(Error::Usage(format!(
            "pool of {} blocks cannot fit one session \
             ({total} rows need {min_blocks} blocks of {})",
            kv.num_blocks, kv.block_size
        )));
    }
    let shared = Workload::random(total, d, 0x9A9E_0000);
    let conts: Vec<Workload> = (0..sessions)
        .map(|s| Workload::random(total, d, 0x9A9E_0100 + s as u64))
        .collect();

    let mut table = SessionTable::new(SessionConfig {
        lanes: sessions,
        max_sessions: sessions,
        mode,
        kv,
        ..SessionConfig::default()
    })?;
    // Parent prefills the shared prefix, then the forks share it.
    let parent = table.open(d)?;
    for t in 0..prefix {
        let (q, k, v) = row(&shared, &conts, prefix, 0, t);
        table.step(DecodeStepRequest {
            session: parent,
            q,
            k,
            v,
        })?;
    }
    let mut ids = vec![parent];
    for _ in 1..sessions {
        ids.push(table.fork(parent)?);
    }
    let shared_blocks = table.pool_shared_blocks();
    let mut peak_used = table.pool_used_blocks();

    // Continuation: one step per session per wave, deferred sessions
    // first next wave (the serving loop's rotation).
    let mut cursors = vec![prefix; sessions];
    let mut deferred: Vec<u64> = Vec::new();
    let mut waves = 0u64;
    let mut deferrals = 0u64;
    while cursors.iter().any(|&c| c < total) {
        let mut order: Vec<usize> = (0..sessions).collect();
        order.sort_by_key(|&s| (!deferred.contains(&ids[s]), s));
        deferred.clear();
        let mut reqs = Vec::new();
        let mut members = Vec::new();
        for &s in &order {
            if cursors[s] < total {
                let (q, k, v) = row(&shared, &conts, prefix, s, cursors[s]);
                reqs.push(DecodeStepRequest {
                    session: ids[s],
                    q,
                    k,
                    v,
                });
                members.push(s);
            }
        }
        let results = table.step_wave(&reqs);
        waves += 1;
        peak_used = peak_used.max(table.pool_used_blocks());
        let mut progressed = false;
        for (res, s) in results.into_iter().zip(members) {
            match res {
                Ok(_) => {
                    cursors[s] += 1;
                    progressed = true;
                }
                Err(Error::AdmissionDeferred(_)) => {
                    deferrals += 1;
                    deferred.push(ids[s]);
                }
                Err(e) => return Err(e),
            }
        }
        if !progressed {
            return Err(Error::Coordinator(format!(
                "paging episode stalled at pool size {}",
                kv.num_blocks
            )));
        }
    }

    let transcripts = ids
        .iter()
        .map(|&id| table.close(id).expect("session open"))
        .collect();
    Ok(EpisodeReport {
        waves,
        deferrals,
        peak_used_blocks: peak_used,
        shared_blocks,
        preemptions: table.preemptions(),
        transcripts,
    })
}

/// Run the study over the given pool sizes. Every pool must at least
/// fit one full session (`prefix + steps` rows) — smaller pools can
/// never serve the workload and are a usage error.
pub fn run(
    pool_blocks: &[usize],
    sessions: usize,
    prefix: usize,
    steps: usize,
    d: usize,
    block_size: usize,
) -> Result<PagingResult> {
    if sessions == 0 || steps == 0 || d == 0 || block_size == 0 {
        return Err(Error::Usage(format!(
            "paging study needs sessions/steps/d/block_size ≥ 1 \
             (got {sessions}/{steps}/{d}/{block_size})"
        )));
    }
    let total = prefix + steps;
    let shared = Workload::random(total, d, 0x9A9E_0000);
    let conts: Vec<Workload> = (0..sessions)
        .map(|s| Workload::random(total, d, 0x9A9E_0100 + s as u64))
        .collect();

    // Unpressured contiguous baselines: session s's expected rows are
    // the chain over its full (prefix + continuation) row sequence.
    // (The episodes themselves regenerate identical workloads from the
    // same seeds — see `run_episode`.)
    let baselines: Vec<Vec<Vec<f32>>> = (0..sessions)
        .map(|s| {
            let mut chain = DecodeSession::new(DecodeKind::MemoryFree, d);
            for t in 0..total {
                let (q, k, v) = row(&shared, &conts, prefix, s, t);
                chain.step(q, k, v).map(|_| ()).map_err(|e| {
                    Error::Coordinator(format!("baseline chain failed: {e}"))
                })?;
            }
            Ok(chain.outputs().clone())
        })
        .collect::<Result<_>>()?;

    let mut points = Vec::new();
    for &num_blocks in pool_blocks {
        let ep = run_episode(
            None,
            sessions,
            prefix,
            steps,
            d,
            KvCacheConfig {
                block_size,
                num_blocks,
            },
        )?;
        // Bit-identity against the unpressured chains (forks own only
        // their continuation rows).
        let mut bit_identical = true;
        for (s, transcript) in ep.transcripts.iter().enumerate() {
            let expect: &[Vec<f32>] = if s == 0 {
                &baselines[0]
            } else {
                &baselines[s][prefix..]
            };
            bit_identical &= transcript.as_slice() == expect;
        }
        points.push(PagingPoint {
            num_blocks,
            peak_used_blocks: ep.peak_used_blocks,
            shared_blocks: ep.shared_blocks,
            preemptions: ep.preemptions,
            deferrals: ep.deferrals,
            waves: ep.waves,
            bit_identical,
        });
    }
    Ok(PagingResult {
        sessions,
        prefix,
        steps,
        d,
        block_size,
        points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ample_pool_shares_prefix_without_preempting() {
        let r = run(&[32], 3, 4, 2, 4, 2).unwrap();
        let p = r.point(32).unwrap();
        assert_eq!(p.preemptions, 0, "ample pool needs no preemption");
        assert_eq!(p.deferrals, 0);
        assert_eq!(
            p.shared_blocks, 2,
            "prefix/block_size = 4/2 blocks shared across forks"
        );
        assert!(p.bit_identical, "transcripts match the contiguous chains");
        assert!(p.peak_occupancy() <= 1.0);
    }

    #[test]
    fn scarce_pool_preempts_but_stays_bit_identical() {
        // 3 sessions × 6 rows at block_size 2 want 5 blocks even with
        // the prefix shared (2 shared + 3 private tails); a 4-block
        // pool forces preemption. Correctness must not budge.
        let r = run(&[4], 3, 4, 2, 4, 2).unwrap();
        let p = r.point(4).unwrap();
        assert!(
            p.preemptions > 0,
            "a 4-block pool under a 5-block demand must preempt"
        );
        assert!(
            p.bit_identical,
            "pressure may cost waves, never correctness"
        );
        assert!(p.peak_used_blocks <= 4, "occupancy never exceeds capacity");
    }

    #[test]
    fn pool_smaller_than_one_session_is_a_usage_error() {
        let err = run(&[2], 2, 4, 2, 4, 2);
        assert!(matches!(err, Err(Error::Usage(_))));
    }

    #[test]
    fn table_lists_every_pool_size() {
        let r = run(&[32, 16], 2, 2, 2, 4, 2).unwrap();
        let text = r.table().render();
        assert!(text.contains("bit-identical"));
        assert!(r.point(16).is_some() && r.point(8).is_none());
    }
}
