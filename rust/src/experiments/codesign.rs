//! Codesign study: what does hiding the softmax division buy in
//! fabric terms?
//!
//! FLASH-D (see [`crate::attention::flashd`]) removes the divider from
//! the dataflow and folds the max/sum bookkeeping into a single
//! log-sum-exp scan. This driver quantifies everything the simulator
//! can see about that trade against the paper's reordered variant, per
//! attention head, across sequence lengths:
//!
//! * **nodes** — functional units in the compiled graph
//!   ([`Engine::node_count`](crate::sim::Engine::node_count)), the
//!   area proxy;
//! * **FIFO slots** — the sum of every inferred channel capacity, the
//!   on-fabric buffering the mapping needs
//!   (reordered pays an `s_bypass` of N+2, FLASH-D is depth-2
//!   everywhere, so its total is *constant* in N);
//! * **long FIFOs** — how many channels the depth inference classified
//!   as reconvergence buffers;
//! * **cycles** — completion time of one head under the default
//!   scheduler (both variants stream N² scores, so this checks the
//!   smaller graph gives nothing back);
//! * **max |Δ|** — accumulation error vs the f64 oracle (the EMA
//!   output form renormalizes every step, so error stays comparable).
//!
//! The headline the tests pin down: **strictly fewer nodes and FIFO
//! slots than the reordered variant at every N**, equal-length
//! streaming schedule, same error order.

use crate::attention::reference::max_abs_diff;
use crate::attention::workload::Workload;
use crate::attention::{DepthPolicy, Variant};
use crate::report::Table;
use crate::sim::Capacity;
use crate::Result;

/// One (variant, N) codesign measurement.
#[derive(Clone, Debug)]
pub struct CodesignPoint {
    /// Sequence length.
    pub n: usize,
    /// Functional units in the compiled head.
    pub nodes: usize,
    /// Total bounded FIFO capacity (slots) across every channel.
    pub fifo_slots: usize,
    /// Channels the depth inference classified as long.
    pub long_fifos: usize,
    /// Completion cycles for one head.
    pub cycles: u64,
    /// max |Δ| vs the f64 oracle.
    pub max_err: f32,
}

/// Full codesign study: one point series per measured variant.
#[derive(Clone, Debug)]
pub struct CodesignResult {
    /// Head dimension all points share.
    pub d: usize,
    /// Per-variant series, in measurement order.
    pub series: Vec<(Variant, Vec<CodesignPoint>)>,
}

impl CodesignResult {
    /// Look up one measurement.
    pub fn point(&self, variant: Variant, n: usize) -> Option<&CodesignPoint> {
        self.series
            .iter()
            .find(|(v, _)| *v == variant)
            .and_then(|(_, pts)| pts.iter().find(|p| p.n == n))
    }

    /// Render the per-head codesign table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!("Codesign per head (d={}): FLASH-D vs reordered", self.d),
            &["variant", "N", "nodes", "fifo slots", "long fifos", "cycles", "max |Δ|"],
        );
        for (variant, pts) in &self.series {
            for p in pts {
                t.row(&[
                    variant.name().into(),
                    p.n.to_string(),
                    p.nodes.to_string(),
                    p.fifo_slots.to_string(),
                    p.long_fifos.to_string(),
                    p.cycles.to_string(),
                    format!("{:.2e}", p.max_err),
                ]);
            }
        }
        t
    }
}

/// Measure the reordered and FLASH-D prefill heads at each `n` with
/// inferred FIFO depths, and return the per-variant series.
pub fn run(ns: &[usize], d: usize) -> Result<CodesignResult> {
    let mut series = Vec::new();
    for variant in [Variant::Reordered, Variant::FlashD] {
        let mut pts = Vec::with_capacity(ns.len());
        for &n in ns {
            let w = Workload::random(n, d, 0xC0DE);
            let gold = variant.oracle_f64(&w);
            let mut built = variant.build_with_policy(&w, DepthPolicy::Inferred)?;
            let nodes = built.engine.node_count();
            let mut fifo_slots = 0usize;
            let mut long_fifos = 0usize;
            for c in built.engine.depth_report() {
                if let Capacity::Bounded(k) = c.capacity {
                    fifo_slots += k;
                }
                if c.is_long {
                    long_fifos += 1;
                }
            }
            let (got, summary) = built.run()?;
            pts.push(CodesignPoint {
                n,
                nodes,
                fifo_slots,
                long_fifos,
                cycles: summary.cycles,
                max_err: max_abs_diff(&got, &gold),
            });
        }
        series.push((variant, pts));
    }
    Ok(CodesignResult {
        d,
        series,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flashd_is_strictly_smaller_than_reordered_at_every_n() {
        let r = run(&[16, 64], 4).unwrap();
        for n in [16usize, 64] {
            let re = r.point(Variant::Reordered, n).unwrap();
            let fd = r.point(Variant::FlashD, n).unwrap();
            assert!(
                fd.nodes < re.nodes,
                "n={n}: flashd {} nodes vs reordered {}",
                fd.nodes,
                re.nodes
            );
            assert!(
                fd.fifo_slots < re.fifo_slots,
                "n={n}: flashd {} slots vs reordered {}",
                fd.fifo_slots,
                re.fifo_slots
            );
        }
    }

    #[test]
    fn flashd_buffering_is_constant_and_reordered_grows_with_n() {
        let r = run(&[16, 64], 4).unwrap();
        let fd16 = r.point(Variant::FlashD, 16).unwrap();
        let fd64 = r.point(Variant::FlashD, 64).unwrap();
        assert_eq!(fd16.long_fifos, 0);
        assert_eq!(fd64.long_fifos, 0);
        assert_eq!(
            fd16.fifo_slots, fd64.fifo_slots,
            "depth-2-everywhere ⇒ slots independent of N"
        );
        let re16 = r.point(Variant::Reordered, 16).unwrap();
        let re64 = r.point(Variant::Reordered, 64).unwrap();
        assert!(re16.long_fifos >= 1, "reordered carries s_bypass");
        assert!(
            re64.fifo_slots > re16.fifo_slots,
            "the bypass grows with N"
        );
    }

    #[test]
    fn both_variants_stay_within_oracle_bounds() {
        let r = run(&[16, 64], 4).unwrap();
        for (v, pts) in &r.series {
            for p in pts {
                assert!(p.max_err < 1e-4, "{v} n={}: {}", p.n, p.max_err);
                assert!(p.cycles > 0, "{v} n={}: no cycles recorded", p.n);
            }
        }
    }

    #[test]
    fn table_lists_both_series() {
        let r = run(&[16], 4).unwrap();
        let rendered = r.table().render();
        assert!(rendered.contains("flashd"));
        assert!(rendered.contains("reordered"));
    }
}
