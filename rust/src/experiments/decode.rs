//! Decode scaling study: per-step cost and memory of autoregressive
//! decode vs cache length.
//!
//! For each decode mapping ([`DecodeKind`]) and cache length this
//! driver builds one decode step under inferred depths and reports:
//! cycles (≈ len + fill at II = 1), cycles per cached key, peak FIFO
//! occupancy, and the inferred long-FIFO depth — the causal-aware
//! bound. The table states the extension's claim directly: the
//! memory-free step stays O(1) while the buffered step's bypass grows
//! as len+2.

use crate::attention::decode::{self, DecodeKind};
use crate::attention::workload::Workload;
use crate::attention::DepthPolicy;
use crate::report::Table;
use crate::sim::metrics::{classify_occupancy, OccupancyClass};
use crate::Result;

/// Per-(kind, len) measurement.
#[derive(Clone, Debug)]
pub struct DecodePoint {
    /// Cached K/V rows the step attends.
    pub len: usize,
    /// Cycles to completion.
    pub cycles: u64,
    /// Cycles per cached key (→ ~1 at II = 1 for long caches).
    pub cycles_per_key: f64,
    /// Largest per-channel peak occupancy (elements).
    pub peak_elems: usize,
    /// Inferred long-FIFO depth (`None` when every FIFO is short).
    pub long_depth: Option<usize>,
    /// The causal-aware bound [`decode::step_long_fifo_bound`].
    pub bound: usize,
}

/// Full decode scaling study.
#[derive(Clone, Debug)]
pub struct DecodeResult {
    /// Head dimension used.
    pub d: usize,
    /// `(kind, points ascending in len)`.
    pub series: Vec<(DecodeKind, Vec<DecodePoint>)>,
}

impl DecodeResult {
    /// Growth class of a kind's peak occupancy vs cache length.
    pub fn classification(&self, kind: DecodeKind) -> OccupancyClass {
        let (_, points) = self
            .series
            .iter()
            .find(|(k, _)| *k == kind)
            .expect("kind present");
        let samples: Vec<(usize, usize)> = points
            .iter()
            .map(|p| (p.len, p.peak_elems + 1))
            .collect();
        classify_occupancy(&samples)
    }

    /// Look up one point.
    pub fn point(&self, kind: DecodeKind, len: usize) -> Option<&DecodePoint> {
        self.series
            .iter()
            .find(|(k, _)| *k == kind)
            .and_then(|(_, ps)| ps.iter().find(|p| p.len == len))
    }

    /// Render the study table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!("Decode step vs cache length (d={})", self.d),
            &[
                "kind",
                "len",
                "cycles",
                "cycles/key",
                "peak FIFO (elems)",
                "long depth (inferred)",
                "bound",
            ],
        );
        for (kind, points) in &self.series {
            for p in points {
                t.row(&[
                    kind.name().into(),
                    p.len.to_string(),
                    p.cycles.to_string(),
                    format!("{:.2}", p.cycles_per_key),
                    p.peak_elems.to_string(),
                    p.long_depth
                        .map(|d| d.to_string())
                        .unwrap_or_else(|| "- (all short)".into()),
                    p.bound.to_string(),
                ]);
            }
            t.row(&[
                format!("{kind} growth"),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("{:?}", self.classification(*kind)),
                "-".into(),
                "-".into(),
            ]);
        }
        t
    }
}

/// Run the study over ascending cache lengths (each ≥ 1).
pub fn run(lens: &[usize], d: usize) -> Result<DecodeResult> {
    let mut series = Vec::new();
    for kind in DecodeKind::ALL {
        let mut points = Vec::new();
        for &len in lens {
            let w = Workload::random(len, d, 0xDEC0DE);
            let mut built = decode::build_step(
                kind,
                &w.q[len - 1],
                &w.k,
                &w.v,
                DepthPolicy::Inferred,
            )?;
            let (_, summary) = built.run()?;
            let peak_elems = summary
                .channel_stats
                .iter()
                .map(|(_, st)| st.peak_occupancy_elems)
                .max()
                .unwrap_or(0);
            let long_depth = summary
                .depths
                .iter()
                .filter(|c| c.is_long)
                .map(|c| c.inferred)
                .max();
            points.push(DecodePoint {
                len,
                cycles: summary.cycles,
                cycles_per_key: summary.cycles as f64 / len as f64,
                peak_elems,
                long_depth,
                bound: decode::step_long_fifo_bound(kind, len),
            });
        }
        series.push((kind, points));
    }
    Ok(DecodeResult { d, series })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memfree_is_constant_and_buffered_linear() {
        let r = run(&[4, 16, 64], 4).unwrap();
        assert_eq!(
            r.classification(DecodeKind::MemoryFree),
            OccupancyClass::Constant
        );
        assert_eq!(
            r.classification(DecodeKind::Buffered),
            OccupancyClass::Linear
        );
    }

    #[test]
    fn inferred_long_depth_tracks_the_causal_bound() {
        let r = run(&[4, 16, 64], 4).unwrap();
        for len in [4usize, 16, 64] {
            let p = r.point(DecodeKind::Buffered, len).unwrap();
            assert_eq!(p.long_depth, Some(len + 2), "buffered len={len}");
            assert_eq!(p.bound, len + 2);
            let p = r.point(DecodeKind::MemoryFree, len).unwrap();
            assert_eq!(p.long_depth, None, "memfree len={len}");
            assert!(p.peak_elems <= 2, "memfree len={len}: O(1) peak");
        }
    }

    #[test]
    fn decode_steps_run_near_ii_1() {
        let r = run(&[16, 64], 4).unwrap();
        for (kind, points) in &r.series {
            for p in points {
                assert!(
                    p.cycles_per_key < 3.0,
                    "{kind} len={}: {:.2} cycles/key — pipeline not streaming",
                    p.len,
                    p.cycles_per_key
                );
            }
        }
    }

    #[test]
    fn table_reports_growth_classes() {
        let r = run(&[4, 16], 4).unwrap();
        let text = r.table().render();
        assert!(text.contains("memfree growth"));
        assert!(text.contains("all short"));
    }
}
