//! Table 1 — demonstrate each Parallel-Pattern node's behaviour on a
//! small concrete stream (the executable version of the paper's table).

use crate::report::Table;
use crate::sim::{Elem, GraphBuilder};

/// One row per node: the behaviour demonstrated on input `1..=6`.
pub fn run() -> Table {
    let mut t = Table::new(
        "Table 1 — Parallel-Pattern node semantics (input stream 1..6)",
        &["node", "config", "output stream"],
    );
    let input: Vec<Elem> = (1..=6).map(|i| Elem::Scalar(i as f32)).collect();

    let demo = |mk: &dyn Fn(&mut GraphBuilder, crate::sim::ChannelId, crate::sim::ChannelId)| {
        let mut g = GraphBuilder::new();
        let a = g.short_fifo("in").unwrap();
        let b = g.short_fifo("out").unwrap();
        g.source_vec("src", a, input.clone()).unwrap();
        mk(&mut g, a, b);
        let h = g.sink("sink", b, None).unwrap();
        let mut e = g.build().unwrap();
        e.run(10_000).unwrap();
        let vals: Vec<String> = h
            .elems()
            .iter()
            .map(|e| format!("{e}"))
            .collect();
        vals.join(" ")
    };

    t.row(&[
        "Map".into(),
        "f = x·10".into(),
        demo(&|g, a, b| {
            g.map("map", a, b, |x| Elem::Scalar(x.scalar() * 10.0)).unwrap();
        }),
    ]);
    t.row(&[
        "Reduce".into(),
        "n=3, init=0, f=+".into(),
        demo(&|g, a, b| {
            g.reduce("red", a, b, 3, 0.0, |x, y| x + y).unwrap();
        }),
    ]);
    t.row(&[
        "MemReduce".into(),
        "n=3, init=0⃗₂, f=+ (x duplicated to 2-vec)".into(),
        {
            // MemReduce needs vector inputs: stage a Map first.
            let mut g = GraphBuilder::new();
            let a = g.short_fifo("in").unwrap();
            let m = g.short_fifo("mid").unwrap();
            let b = g.short_fifo("out").unwrap();
            g.source_vec("src", a, input.clone()).unwrap();
            g.map("tovec", a, m, |x| Elem::vector(&[x.scalar(), x.scalar()]))
                .unwrap();
            g.mem_reduce("mred", m, b, 3, vec![0.0, 0.0], |acc, x| {
                acc.iter().zip(x.as_vector()).map(|(p, q)| p + q).collect()
            })
            .unwrap();
            let h = g.sink("sink", b, None).unwrap();
            let mut e = g.build().unwrap();
            e.run(10_000).unwrap();
            h.elems()
                .iter()
                .map(|e| format!("{e}"))
                .collect::<Vec<_>>()
                .join(" ")
        },
    ]);
    t.row(&[
        "Repeat".into(),
        "n=2".into(),
        demo(&|g, a, b| {
            g.repeat("rep", a, b, 2).unwrap();
        }),
    ]);
    t.row(&[
        "Scan".into(),
        "n=3, init=0, updt=+, f=state".into(),
        demo(&|g, a, b| {
            g.scan(
                "scan",
                a,
                b,
                3,
                Elem::Scalar(0.0),
                |st, x| Elem::Scalar(st.scalar() + x.scalar()),
                |st, _| st.clone(),
            )
            .unwrap();
        }),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_rows_show_expected_streams() {
        let rendered = run().render();
        // Map: 1..6 × 10.
        assert!(rendered.contains("10 20 30 40 50 60"), "{rendered}");
        // Reduce(3,+): 1+2+3, 4+5+6.
        assert!(rendered.contains("6 15"), "{rendered}");
        // Repeat(2).
        assert!(rendered.contains("1 1 2 2 3 3 4 4 5 5 6 6"), "{rendered}");
        // Scan(3,+): 1 3 6 | 4 9 15.
        assert!(rendered.contains("1 3 6 4 9 15"), "{rendered}");
        // MemReduce: vec[6, 6] then vec[15, 15].
        assert!(rendered.contains("vec[6.0, 6.0]") || rendered.contains("vec[6, 6]"),
                "{rendered}");
    }
}
