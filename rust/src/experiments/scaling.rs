//! Intermediate-memory growth study: O(N) vs O(1).
//!
//! Runs the paper's four prefill variants in their paper configuration
//! across a range of sequence lengths and reports peak intermediate
//! memory (total words buffered in FIFOs at the high-water mark) plus
//! total cycles. The growth classification reproduces the paper's
//! §3/§4 asymptotic claims; cycles ≈ N² + fill confirms full
//! throughput at every size. (The decode-side study lives in
//! [`super::decode`].)

use crate::attention::workload::Workload;
use crate::attention::{FifoPlan, Variant};
use crate::report::Table;
use crate::sim::metrics::{classify_occupancy, OccupancyClass};
use crate::Result;

/// Per-(variant, N) measurement.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    /// Sequence length.
    pub n: usize,
    /// Peak FIFO words, *excluding* operand-delivery channels (the
    /// cyclic K/V sources hold d-wide rows regardless of algorithm).
    pub peak_words: usize,
    /// Peak of the variant's long FIFOs in elements (0 if none).
    pub peak_long_elems: usize,
    /// Cycles to completion.
    pub cycles: u64,
    /// Node ticks the (event-driven) scheduler executed.
    pub ticks_executed: u64,
    /// Node ticks skipped vs. the dense loop over the same span.
    pub ticks_skipped: u64,
}

/// Full scaling study.
#[derive(Clone, Debug)]
pub struct ScalingResult {
    /// Head dimension used.
    pub d: usize,
    /// `(variant, points ascending in n)`.
    pub series: Vec<(Variant, Vec<ScalePoint>)>,
}

impl ScalingResult {
    /// Growth class of a variant's *long-FIFO* occupancy.
    pub fn classification(&self, variant: Variant) -> OccupancyClass {
        let (_, points) = self
            .series
            .iter()
            .find(|(v, _)| *v == variant)
            .expect("variant present");
        let samples: Vec<(usize, usize)> = points
            .iter()
            // +1 word so the O(1) case is a nonzero constant series.
            .map(|p| (p.n, p.peak_long_elems + 1))
            .collect();
        classify_occupancy(&samples)
    }

    /// Render the scaling table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            format!("Intermediate memory vs N (d={})", self.d),
            &["variant", "N", "peak long-FIFO (elems)", "peak FIFO words", "cycles", "cycles/N^2", "ticks exec/skipped"],
        );
        for (v, points) in &self.series {
            for p in points {
                t.row(&[
                    v.name().into(),
                    p.n.to_string(),
                    p.peak_long_elems.to_string(),
                    p.peak_words.to_string(),
                    p.cycles.to_string(),
                    format!("{:.3}", p.cycles as f64 / (p.n * p.n) as f64),
                    format!("{}/{}", p.ticks_executed, p.ticks_skipped),
                ]);
            }
            t.row(&[
                format!("{v} growth"),
                "-".into(),
                format!("{:?}", self.classification(*v)),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        }
        t
    }
}

/// Run the study over `sizes` (ascending recommended).
pub fn run(sizes: &[usize], d: usize) -> Result<ScalingResult> {
    let mut series = Vec::new();
    for variant in Variant::PAPER {
        let mut points = Vec::new();
        for &n in sizes {
            let w = Workload::random(n, d, 0x5CA1E);
            let mut built = variant.build(&w, &FifoPlan::paper(n))?;
            let (_, summary) = built.run()?;
            let peak_long_elems = variant
                .long_fifos()
                .iter()
                .filter_map(|f| summary.peak_elems(f))
                .max()
                .unwrap_or(0);
            points.push(ScalePoint {
                n,
                peak_words: summary.total_peak_words(),
                peak_long_elems,
                cycles: summary.cycles,
                ticks_executed: summary.sched.node_ticks_executed,
                ticks_skipped: summary.sched.node_ticks_skipped,
            });
        }
        series.push((variant, points));
    }
    Ok(ScalingResult { d, series })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn growth_classes_match_paper() {
        let r = run(&[8, 16, 32, 64], 4).unwrap();
        assert_eq!(r.classification(Variant::Naive), OccupancyClass::Linear);
        assert_eq!(r.classification(Variant::Scaled), OccupancyClass::Linear);
        assert_eq!(r.classification(Variant::Reordered), OccupancyClass::Linear);
        assert_eq!(
            r.classification(Variant::MemoryFree),
            OccupancyClass::Constant
        );
    }

    #[test]
    fn cycles_scale_quadratically_at_full_throughput() {
        let r = run(&[16, 32], 4).unwrap();
        for (v, points) in &r.series {
            for p in points {
                let ratio = p.cycles as f64 / (p.n * p.n) as f64;
                assert!(
                    ratio < 1.6,
                    "{v} at N={}: cycles/N² = {ratio} — not full throughput",
                    p.n
                );
            }
        }
    }

    #[test]
    fn memfree_peak_long_is_zero() {
        let r = run(&[16, 32], 4).unwrap();
        let (_, points) = r
            .series
            .iter()
            .find(|(v, _)| *v == Variant::MemoryFree)
            .unwrap();
        assert!(points.iter().all(|p| p.peak_long_elems == 0));
    }
}
