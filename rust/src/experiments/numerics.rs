//! Numeric validation: every dataflow variant vs its f64 oracle.
//!
//! Each implementation must compute *the same function* as its oracle
//! — full attention for the prefill variants, causal attention for the
//! masked ones, the final causal row for the decode step
//! ([`Variant::oracle_f64`]). This driver quantifies the agreement
//! (max |Δ|) on a shared random workload, including the adversarial
//! large-magnitude case where the unscaled naive softmax overflows —
//! demonstrating why §4 adopts softmax-with-scaling.

use crate::attention::reference::max_abs_diff;
use crate::attention::workload::Workload;
use crate::attention::{FifoPlan, Variant};
use crate::report::Table;
use crate::Result;

/// One (variant, workload) agreement measurement.
#[derive(Clone, Debug)]
pub struct NumericsPoint {
    /// Variant measured.
    pub variant: Variant,
    /// Workload label.
    pub workload: &'static str,
    /// max |Δ| vs f64 oracle (NaN ⇒ non-finite output).
    pub max_err: f32,
}

/// Full numerics study.
#[derive(Clone, Debug)]
pub struct NumericsResult {
    /// All measurements.
    pub points: Vec<NumericsPoint>,
}

impl NumericsResult {
    /// Look up one measurement.
    pub fn err(&self, variant: Variant, workload: &str) -> Option<f32> {
        self.points
            .iter()
            .find(|p| p.variant == variant && p.workload == workload)
            .map(|p| p.max_err)
    }

    /// Render the agreement table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Numeric agreement vs f64 reference (max |Δ|)",
            &["variant", "workload", "max |Δ|"],
        );
        for p in &self.points {
            let err = if p.max_err.is_nan() {
                "NaN/overflow".to_string()
            } else {
                format!("{:.2e}", p.max_err)
            };
            t.row(&[p.variant.name().into(), p.workload.into(), err]);
        }
        t
    }
}

/// Run all variants on a normal and an adversarial workload.
pub fn run(n: usize, d: usize) -> Result<NumericsResult> {
    let normal = Workload::random(n, d, 0xACC);
    let adversarial = Workload::large_magnitude(n.min(16), d, 0xACC, 200.0);
    let mut points = Vec::new();
    for (label, w) in [("normal", &normal), ("adversarial", &adversarial)] {
        for variant in Variant::ALL {
            let gold = variant.oracle_f64(w);
            let mut built = variant.build(w, &FifoPlan::paper(w.n))?;
            let (got, _) = built.run()?;
            points.push(NumericsPoint {
                variant,
                workload: label,
                max_err: max_abs_diff(&got, &gold),
            });
        }
    }
    Ok(NumericsResult { points })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_variants_agree_on_normal_workload() {
        let r = run(16, 8).unwrap();
        for v in Variant::ALL {
            let err = r.err(v, "normal").unwrap();
            assert!(err < 1e-4, "{v}: {err}");
        }
    }

    #[test]
    fn naive_overflows_adversarial_others_do_not() {
        let r = run(16, 8).unwrap();
        // The unscaled softmax overflows f32 → NaN against the oracle.
        assert!(r.err(Variant::Naive, "adversarial").unwrap().is_nan());
        // Every scaling-based variant — prefill, causal, decode — stays
        // finite and accurate on the same inputs.
        for v in [
            Variant::Scaled,
            Variant::Reordered,
            Variant::MemoryFree,
            Variant::CausalScaled,
            Variant::CausalReordered,
            Variant::CausalMemoryFree,
            Variant::Decode,
            Variant::FlashD,
        ] {
            let err = r.err(v, "adversarial").unwrap();
            assert!(err.is_finite() && err < 1e-3, "{v}: {err}");
        }
    }

    #[test]
    fn table_marks_overflow() {
        let r = run(16, 8).unwrap();
        assert!(r.table().render().contains("NaN/overflow"));
    }
}
